"""Quickstart: the paper's pipeline in 60 lines.

1. take float weights,
2. store them as (N-1)-bit normalized posit codes (ExPAN(N)D's format),
3. run a matmul through the PoFx datapath (decode -> FxP -> MXU),
4. compare against fp32 and against FxP8 storage.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizers import QuantSpec, quantize, storage_bits
from repro.kernels.ops import quant_matmul

rng = np.random.default_rng(0)
w = jnp.asarray(rng.normal(0, 0.05, (512, 256)), jnp.float32)   # trained-ish
x = jnp.asarray(rng.normal(0, 1.0, (8, 512)), jnp.float32)

y_ref = x @ w

print(f"{'format':<14} {'bits/w':>7} {'storage':>10} {'matmul rel err':>15}")
for name, spec in [
    ("fxp8", QuantSpec(kind="fxp", M=8, F=7)),
    ("posit(8,2)", QuantSpec(kind="posit", N=8, ES=2)),
    ("pofx(7,2)", QuantSpec(kind="pofx", N=8, ES=2, M=8)),   # the paper
    ("pofx(5,2)", QuantSpec(kind="pofx", N=6, ES=2, M=8)),
]:
    qt = quantize(w, spec, axis=-1)
    y = quant_matmul(x, qt, out_dtype=jnp.float32)
    rel = float(jnp.linalg.norm(y - y_ref) / jnp.linalg.norm(y_ref))
    bits = storage_bits(qt) / w.size
    print(f"{name:<14} {bits:7.2f} {storage_bits(qt)/8/1024:8.1f}KiB {rel:15.5f}")

# the same QuantizedTensor flows through jit / scan / checkpointing:
qt = quantize(w, QuantSpec(kind="pofx", N=8, ES=2, M=8), axis=-1)
fast = jax.jit(lambda x, q: quant_matmul(x, q))
print("jit ok:", fast(x, qt).shape, "codes dtype:", qt.codes.dtype)

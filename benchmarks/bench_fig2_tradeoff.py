"""Fig. 2: error / decode-cost / memory-footprint triple per scheme.

The FPGA CPD column becomes two measurable TPU analogues: static decode op
count (jaxpr primitive count — circuit-depth proxy) and measured CPU decode
wall-time per weight. Memory footprint is exact stored bits/weight.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import fxp
from repro.core.pofx import pofx_normalized
from repro.core.posit import posit_decode
from repro.core.quantizers import QuantSpec, quantize, storage_bits

from .common import (avg_abs_rel_error, jaxpr_ops, vgg_like_weights,
                     wall_time, write_csv)


def run():
    w = vgg_like_weights(1 << 18)
    rows = []
    specs = [
        ("fp32", QuantSpec(kind="fp32")),
        ("bf16", QuantSpec(kind="bf16")),
        ("fxp8", QuantSpec(kind="fxp", M=8, F=7)),
        ("fxp16", QuantSpec(kind="fxp", M=16, F=15)),
        ("posit(8,2)", QuantSpec(kind="posit", N=8, ES=2)),
        ("posit(6,2)", QuantSpec(kind="posit", N=6, ES=2)),
        ("pofx(7,2)", QuantSpec(kind="pofx", N=8, ES=2, M=8)),
        ("pofx(5,2)", QuantSpec(kind="pofx", N=6, ES=2, M=8)),
    ]
    codes8 = jnp.asarray(np.random.default_rng(0).integers(0, 128, 1 << 18),
                         jnp.int32)
    decoders = {
        "fxp8": lambda c: fxp.fxp_dequantize(c, 7),
        "fxp16": lambda c: fxp.fxp_dequantize(c, 15),
        "posit(8,2)": lambda c: posit_decode(c, 8, 2),
        "posit(6,2)": lambda c: posit_decode(c, 6, 2),
        "pofx(7,2)": lambda c: pofx_normalized(c, 8, 2, 8)[0],
        "pofx(5,2)": lambda c: pofx_normalized(c, 6, 2, 8)[0],
    }
    for name, spec in specs:
        # per-tensor pow2 normalizer: the paper's "normalized parameters"
        # assumption (one scale per tensor, negligible overhead)
        import dataclasses
        if spec.kind not in ("fp32", "bf16"):
            spec = dataclasses.replace(spec, scale_mode="tensor_pow2")
        qt = quantize(jnp.asarray(w, jnp.float32), spec)
        wq = np.asarray(qt.dequantize(jnp.float32), np.float64)
        row = {"scheme": name,
               "avg_rel": avg_abs_rel_error(w, wq),
               "bits_per_weight": storage_bits(qt) / w.size}
        if name in decoders:
            fn = decoders[name]
            row["decode_ops"] = jaxpr_ops(fn, codes8)
            row["decode_ns_per_weight"] = wall_time(fn, codes8) / codes8.size * 1e9
        else:
            row["decode_ops"] = 0
            row["decode_ns_per_weight"] = 0.0
        rows.append(row)
    write_csv("fig2_tradeoff", rows)
    by = {r["scheme"]: r for r in rows}
    return rows, {
        # paper Fig 2: posit decode much deeper than fxp; pofx storage wins
        "pofx7_bits": by["pofx(7,2)"]["bits_per_weight"],
        "fxp8_bits": by["fxp8"]["bits_per_weight"],
        "posit_decode_deeper_than_fxp":
            by["posit(8,2)"]["decode_ops"] > by["fxp8"]["decode_ops"],
    }

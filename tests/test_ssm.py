"""SSM mixers: chunked scan vs step-exact sequential recurrence, decode
cache consistency, and state-size invariants (why long_500k is assigned to
these families)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, RunConfig, smoke
from repro.nn import ssm
from repro.nn.models import build_model


@pytest.fixture(scope="module")
def mamba1():
    cfg = smoke(ARCHS["falcon-mamba-7b"])
    p = ssm.mamba1_init(jax.random.PRNGKey(0), cfg)
    return cfg, p


@pytest.fixture(scope="module")
def mamba2():
    cfg = smoke(ARCHS["zamba2-1.2b"])
    p = ssm.mamba2_init(jax.random.PRNGKey(0), cfg)
    return cfg, p


@pytest.mark.parametrize("chunk", [2, 8, 32])
def test_mamba1_chunked_matches_sequential(mamba1, chunk):
    cfg, p = mamba1
    B, S = 2, 32
    xz = jax.random.normal(jax.random.PRNGKey(1), (B, S, 2 * cfg.d_inner))
    y_c, _, h_c = ssm.mamba1_mix(p, xz, cfg, chunk=chunk)
    y_s, _, h_s = ssm.mamba1_mix(p, xz, cfg, chunk=1)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_s),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("chunk", [2, 8, 32])
def test_mamba2_chunked_matches_sequential(mamba2, chunk):
    cfg, p = mamba2
    B, S = 2, 32
    nh = cfg.d_inner // cfg.ssm_head_dim
    zx = jax.random.normal(jax.random.PRNGKey(2),
                           (B, S, 2 * cfg.d_inner + 2 * cfg.ssm_state + nh))
    y_c, _, h_c = ssm.mamba2_mix(p, zx, cfg, chunk=chunk)
    y_s, _, h_s = ssm.mamba2_mix(p, zx, cfg, chunk=1)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_s),
                               atol=2e-5, rtol=2e-5)


def test_mamba1_streaming_decode(mamba1):
    """Step-by-step decode with carried cache == full-sequence mix."""
    cfg, p = mamba1
    B, S = 2, 16
    xz = jax.random.normal(jax.random.PRNGKey(3), (B, S, 2 * cfg.d_inner))
    y_full, _, _ = ssm.mamba1_mix(p, xz, cfg)
    cache = ssm.mamba1_init_cache(cfg, B)
    outs = []
    for t in range(S):
        y, conv, h = ssm.mamba1_mix(p, xz[:, t:t + 1], cfg,
                                    conv_state=cache["conv"],
                                    ssm_state=cache["ssm"])
        cache = {"conv": conv, "ssm": h}
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(y_full), atol=1e-5, rtol=1e-5)


def test_mamba2_streaming_decode(mamba2):
    cfg, p = mamba2
    B, S = 2, 12
    nh = cfg.d_inner // cfg.ssm_head_dim
    zx = jax.random.normal(jax.random.PRNGKey(4),
                           (B, S, 2 * cfg.d_inner + 2 * cfg.ssm_state + nh))
    y_full, _, _ = ssm.mamba2_mix(p, zx, cfg)
    cache = ssm.mamba2_init_cache(cfg, B)
    outs = []
    for t in range(S):
        y, conv, h = ssm.mamba2_mix(p, zx[:, t:t + 1], cfg,
                                    conv_state=cache["conv"],
                                    ssm_state=cache["ssm"])
        cache = {"conv": conv, "ssm": h}
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(y_full), atol=2e-5, rtol=2e-5)


def test_ssm_cache_size_is_seq_independent():
    """The whole point of the long_500k assignment: decode state is O(1)."""
    cfg = smoke(ARCHS["falcon-mamba-7b"])
    model = build_model(cfg, RunConfig(remat="none"))
    small = jax.eval_shape(lambda: model.init_cache(2, 64))
    large = jax.eval_shape(lambda: model.init_cache(2, 1 << 19))
    sz = lambda t: sum(np.prod(l.shape) * l.dtype.itemsize
                       for l in jax.tree.leaves(t))
    assert sz(small) == sz(large)


def test_hybrid_shared_attn_cache_grows_with_seq():
    cfg = smoke(ARCHS["zamba2-1.2b"])
    model = build_model(cfg, RunConfig(remat="none"))
    small = jax.eval_shape(lambda: model.init_cache(2, 64))
    large = jax.eval_shape(lambda: model.init_cache(2, 256))
    sz = lambda t: sum(np.prod(l.shape) * l.dtype.itemsize
                       for l in jax.tree.leaves(t))
    assert sz(large) > sz(small)          # shared attn KV grows
    # ...but only the shared block's cache, not per-mamba-layer
    ssm_small = sum(np.prod(l.shape) for l in jax.tree.leaves(small["ssm"]))
    ssm_large = sum(np.prod(l.shape) for l in jax.tree.leaves(large["ssm"]))
    assert ssm_small == ssm_large

"""Pallas TPU kernel: fused quantized-KV-cache flash-decode attention.

The paper's Move&Store datapath applied to the *decode KV cache* — the term
that dominates HBM traffic per decoded token at long context (weights are
amortized over the batch; the cache is re-read per token per sequence):

    HBM:   K/V stored as byte-wide quantization codes (int8 FxP two's
           complement, or uint8 normalized-posit) + a tiny static
           per-head-dim-channel scale — (8 or fewer)/16 of the bf16 bytes
    VMEM:  each (block_s, Dh) code tile is dequantized on the VPU right
           after the DMA lands (fxp: one int->float multiply; pofx: the
           bit-level Algorithm-1 stages, same as pofx_matmul)
    MXU:   online-softmax flash decode against the dequantized tile, f32
           scratch accumulators (m, l, acc) carried across the S grid axis

Full-precision K/V never round-trips through HBM: the cache is written as
codes (``nn.attention`` quantizes on write) and only ever expands inside
VMEM. The XLA fallback (quantize-on-write, dequantize-on-read via
``core.quantizers.kv_dequantize`` + plain ``decode_attention``) computes the
same math out-of-place and is the oracle this kernel is tested against.

``pos`` is per-slot (B,): entries at or beyond a slot's valid length mask to
-inf exactly like the XLA path, so ragged continuous-batching slots and
zero-padded tail tiles are safe (code 0 decodes to value 0 and is masked
anyway — see tests/test_kernels.py::test_pad_code_zero_decodes_to_zero).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.fxp import fxp_dequantize
from repro.core.quantizers import QuantSpec
from . import vmem_scratch
from .ref import decode_norm_to_fxp

__all__ = ["kv_flash_decode"]

NEG_INF = -1e30

# KV-sequence block length per backend (lane-dim tiles are full head_dim).
DEFAULT_BLOCK_S = {"tpu": 512, "cpu": 128, "gpu": 256}


def _dequant_tile(codes, spec: QuantSpec, scale_row):
    """codes (bs, Dh) int -> f32 values; scale_row (1, Dh) broadcasts."""
    c = codes.astype(jnp.int32)
    if spec.kind == "fxp":
        v = fxp_dequantize(c, spec.F)
    else:  # pofx: bit-level Algorithm 1 on the VPU, then FxP(M, M-1) value
        v = fxp_dequantize(decode_norm_to_fxp(c, spec.N, spec.ES, spec.M),
                           spec.M - 1)
    return v * scale_row


def _kernel(pos_ref, q_ref, kc_ref, ks_ref, vc_ref, vs_ref, o_ref,
            m_ref, l_ref, acc_ref, *, spec: QuantSpec, bs: int, ns: int,
            scale: float):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                     # (R, Dh)
    k = _dequant_tile(kc_ref[0, 0], spec, ks_ref[0, 0])     # (bs, Dh)
    sc = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (R,bs)
    idx = s * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    sc = jnp.where(idx < pos_ref[0, 0], sc, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]                 # (R, 1)
    m_new = jnp.maximum(m_prev, sc.max(axis=-1, keepdims=True))
    p = jnp.exp(sc - m_new)                                 # (R, bs)
    corr = jnp.exp(m_prev - m_new)
    v = _dequant_tile(vc_ref[0, 0], spec, vs_ref[0, 0])     # (bs, Dh)
    m_ref[...] = m_new
    l_ref[...] = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(s == ns - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("spec", "block_s", "interpret",
                                             "out_dtype"))
def kv_flash_decode(q: jax.Array, k_codes: jax.Array, k_scale: jax.Array,
                    v_codes: jax.Array, v_scale: jax.Array, pos: jax.Array,
                    spec: QuantSpec, *, block_s: int | None = None,
                    interpret: bool | None = None,
                    out_dtype=jnp.float32) -> jax.Array:
    """One-token attention against a quantized heads-major cache.

    q:        (B, G, R, Dh) float queries (R = q heads per kv group)
    k_codes:  (B, G, S, Dh) int8/uint8 cache codes (``kv_code_dtype``)
    k_scale:  (B, G, 1, Dh) f32 static per-head-dim-channel normalizer
    v_codes / v_scale: same layouts for V
    pos:      scalar or (B,) valid-prefix lengths (mask: arange(S) < pos)

    Returns (B, G, R, Dh) in ``out_dtype``. Grid is (B, G, S/block_s) with
    the S axis innermost; the online-softmax state lives in VMEM scratch.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, G, R, Dh = q.shape
    S = k_codes.shape[2]
    if v_codes.shape != k_codes.shape:
        raise ValueError(
            f"k/v code shape mismatch: {k_codes.shape} vs {v_codes.shape}")
    for name, sc in (("k_scale", k_scale), ("v_scale", v_scale)):
        if sc.shape[-3:] != (G, 1, Dh):
            # must raise: the (1, Dh) BlockSpec would silently read row 0
            # of a mis-shaped scale while the XLA fallback broadcasts it
            raise ValueError(
                f"kv {name} must be per-head-dim-channel "
                f"(..., {G}, 1, {Dh}); got {sc.shape}")
    if block_s is None:
        block_s = DEFAULT_BLOCK_S.get(jax.default_backend(),
                                      DEFAULT_BLOCK_S["tpu"])
    bs = min(block_s, S)
    pad = (-S) % bs
    if pad and interpret:
        # interpret mode only: pallas's CPU emulation needs block-divisible
        # dims. On TPU the final partial tile is DMA'd as-is (OOB lanes are
        # undefined but finite once dequantized, and idx >= pos masks them
        # to -inf) — explicitly padding there would re-copy the full code
        # caches in HBM per step per layer, eroding the bandwidth win.
        k_codes = jnp.pad(k_codes, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_codes = jnp.pad(v_codes, ((0, 0), (0, 0), (0, pad), (0, 0)))
    ns = (S + pad) // bs
    pos2 = jnp.broadcast_to(jnp.reshape(pos, (-1, 1)), (B, 1)).astype(jnp.int32)
    out = pl.pallas_call(
        functools.partial(_kernel, spec=spec, bs=bs, ns=ns,
                          scale=Dh ** -0.5),
        grid=(B, G, ns),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, g, s: (b, 0)),            # pos
            pl.BlockSpec((1, 1, R, Dh), lambda b, g, s: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, bs, Dh), lambda b, g, s: (b, g, s, 0)),
            pl.BlockSpec((1, 1, 1, Dh), lambda b, g, s: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, bs, Dh), lambda b, g, s: (b, g, s, 0)),
            pl.BlockSpec((1, 1, 1, Dh), lambda b, g, s: (b, g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, R, Dh), lambda b, g, s: (b, g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, G, R, Dh), out_dtype),
        scratch_shapes=[vmem_scratch((R, 1)), vmem_scratch((R, 1)),
                        vmem_scratch((R, Dh))],
        interpret=interpret,
    )(pos2, q.astype(jnp.float32), k_codes, k_scale.astype(jnp.float32),
      v_codes, v_scale.astype(jnp.float32))
    return out

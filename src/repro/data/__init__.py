from .pipeline import (DataConfig, TokenFileReader, synthetic_batch,
                       synthetic_batches, write_token_file)

__all__ = ["DataConfig", "synthetic_batch", "synthetic_batches",
           "TokenFileReader", "write_token_file"]

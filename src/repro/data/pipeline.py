"""Deterministic synthetic LM data + binary memmap reader.

Synthetic stream: Zipf-distributed unigrams overlaid with *induction
patterns* — each sequence repeats a randomly drawn motif of length
``motif_len`` with period ``motif_len`` — so a real LM has signal to learn
(copy heads drive the loss well below the unigram entropy). Batches are a
pure function of (seed, step, host_id): restarts and elastic re-shards
reproduce the exact stream with no data loss, and each host generates only
its own shard (no cross-host traffic, 1000-node posture).

TokenFileReader memory-maps a flat uint16/uint32 token file and serves
fixed-length windows; the same host-sharding contract applies.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "synthetic_batches", "write_token_file",
           "TokenFileReader"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_alpha: float = 1.3
    motif_len: int = 8
    motif_prob: float = 0.8      # fraction of sequences carrying a motif


def _zipf_probs(vocab: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** -alpha
    return p / p.sum()


def _batch_rng(cfg: DataConfig, step: int, host_id: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host_id]))


def synthetic_batch(cfg: DataConfig, step: int, *, host_id: int = 0,
                    n_hosts: int = 1) -> Dict[str, np.ndarray]:
    """One deterministic {tokens, labels} batch (host shard)."""
    assert cfg.global_batch % n_hosts == 0, (cfg.global_batch, n_hosts)
    b = cfg.global_batch // n_hosts
    rng = _batch_rng(cfg, step, host_id)
    probs = _zipf_probs(cfg.vocab_size, cfg.zipf_alpha)
    # +1 so labels are a clean shift of the same stream.
    toks = rng.choice(cfg.vocab_size, size=(b, cfg.seq_len + 1), p=probs)
    has_motif = rng.random(b) < cfg.motif_prob
    motifs = rng.choice(cfg.vocab_size, size=(b, cfg.motif_len), p=probs)
    reps = int(np.ceil((cfg.seq_len + 1) / cfg.motif_len))
    tiled = np.tile(motifs, (1, reps))[:, : cfg.seq_len + 1]
    toks = np.where(has_motif[:, None], tiled, toks).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def synthetic_batches(cfg: DataConfig, *, start_step: int = 0,
                      host_id: int = 0, n_hosts: int = 1
                      ) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield synthetic_batch(cfg, step, host_id=host_id, n_hosts=n_hosts)
        step += 1


# ---------------------------------------------------------------------------
# Binary token file (memmap)
# ---------------------------------------------------------------------------


def write_token_file(path: str, tokens: np.ndarray) -> None:
    """Flat little-endian token file with a tiny self-describing header."""
    tokens = np.asarray(tokens)
    dtype = np.uint16 if tokens.max() < 2**16 else np.uint32
    with open(path, "wb") as f:
        f.write(b"RPTK")
        f.write(np.asarray([1 if dtype == np.uint16 else 2, tokens.size],
                           dtype="<u8").tobytes())
        f.write(tokens.astype(f"<{np.dtype(dtype).str[1:]}").tobytes())


class TokenFileReader:
    """Memory-mapped fixed-window reader over a flat token file.

    Window w of host h at step s is a pure function of (s, h): windows are
    laid out round-robin across hosts, wrapping at the end — deterministic
    resume by step, no shuffle buffer state to checkpoint.
    """

    def __init__(self, path: str, seq_len: int, batch: int, *,
                 host_id: int = 0, n_hosts: int = 1):
        with open(path, "rb") as f:
            magic = f.read(4)
            assert magic == b"RPTK", f"bad token file {path!r}"
            kind, size = np.frombuffer(f.read(16), dtype="<u8")
        dtype = np.uint16 if kind == 1 else np.uint32
        self._data = np.memmap(path, dtype=f"<{np.dtype(dtype).str[1:]}",
                               mode="r", offset=20, shape=(int(size),))
        self.seq_len = seq_len
        self.batch = batch
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.n_windows = (int(size) - 1) // seq_len
        assert self.n_windows > 0

    def read_batch(self, step: int) -> Dict[str, np.ndarray]:
        idx = (step * self.batch * self.n_hosts
               + self.host_id * self.batch
               + np.arange(self.batch)) % self.n_windows
        tok = np.stack([self._data[i * self.seq_len: i * self.seq_len
                                   + self.seq_len + 1] for i in idx])
        tok = tok.astype(np.int32)
        return {"tokens": tok[:, :-1], "labels": tok[:, 1:]}

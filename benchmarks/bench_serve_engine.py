"""Serve-engine throughput under varying request-arrival mixes.

The continuous-batching claim: tokens/s should hold up when requests
arrive staggered (slots refill as others finish) instead of as one
aligned batch — the regime the old one-shot driver could not serve at
all. Three mixes over the same request set:

  burst     — all requests arrive at t=0 (best case for static batching)
  staggered — one request every `gap` decode steps (steady traffic)
  ragged    — burst arrivals but 2x-spread generation lengths (slots
              free at different times; continuous refill does the work)

plus a long-context mix for the quantized KV cache (DESIGN.md §8):

  longctx   — staggered arrivals over long prompts, served three ways:
              bf16 cache, quantized cache via the XLA fallback, quantized
              cache via the fused Pallas flash-decode kernel. Rows record
              the modeled decode KV-cache HBM bytes/token (the
              S-proportional roofline term) so the 2x+ bandwidth win shows
              up in the perf trajectory, and the kernel/fallback runs are
              checked token-identical under greedy sampling.

plus the paged-cache mix (DESIGN.md §10):

  sharedprefix — N requests drawn from K distinct system prompts, served
              by the paged engine: every non-first request of a prompt
              group should hit the radix prefix index and skip its system
              prompt's prefill. Rows record prefill tokens skipped and KV
              bytes per resident token (pool bytes over deduplicated
              resident tokens); the bench's own expected hit count must
              agree with ``ServeEngine.stats()``, and the paged streams
              are checked token-identical to the dense engine's.

``--smoke`` additionally emits the tp=2-vs-tp=1 decode tok/s row (the
ROADMAP bench-trajectory item) by re-running the burst mix at both tp
sizes in a child process with 2 fake CPU devices.

Rows land in experiments/bench/serve_engine.csv. Run standalone
(``python -m benchmarks.bench_serve_engine [--use-kernel]
[--kv-quant fxp8]``) or via ``benchmarks.run``.
"""
from __future__ import annotations

import argparse
import dataclasses
import os

import jax
import numpy as np

from repro.configs import ARCHS, RunConfig, smoke as smoke_cfg
from repro.core.policy import format_spec, parse_kv_spec
from repro.launch.engine import Request, SamplingParams, ServeEngine
from repro.nn.models import (apply_policy, build_model,
                             kv_decode_bytes_per_token)

from .common import write_csv

ARCH = "yi-9b"


@dataclasses.dataclass(frozen=True)
class Sizes:
    n_req: int = 8
    slots: int = 4
    prompt: int = 32
    gen: int = 16
    chunk: int = 8
    long_prompt: int = 96     # "long" for a CPU smoke model; the modeled
    long_gen: int = 16        # bytes/token ratio is context-length-invariant


FULL = Sizes()
# --smoke / tests/test_bench_smoke.py: every mix, variant and claim still
# runs — just few enough tokens that bench bit-rot fails in CI seconds
SMOKE = Sizes(n_req=4, slots=2, prompt=8, gen=6, chunk=4,
              long_prompt=16, long_gen=4)


def _mix_requests(mix: str, vocab: int, sz: Sizes) -> list:
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(sz.n_req):
        gen = sz.gen
        arrival = 0.0
        if mix == "staggered":
            arrival = float(i * (sz.gen // 2))
        elif mix == "ragged":
            gen = sz.gen // 2 if i % 2 else sz.gen
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, vocab, sz.prompt), max_new=gen,
            sampling=SamplingParams(), arrival=arrival))
    return reqs


def _longctx_requests(vocab: int, sz: Sizes) -> list:
    rng = np.random.default_rng(3)
    return [Request(rid=i, prompt=rng.integers(0, vocab, sz.long_prompt),
                    max_new=sz.long_gen, sampling=SamplingParams(),  # greedy
                    arrival=float(i * (sz.long_gen // 2)))
            for i in range(sz.n_req)]


def _run_longctx(cfg, params, kv_spec, kv_kernel, use_kernel, sz: Sizes):
    model = build_model(cfg, RunConfig(remat="none"), use_kernel=use_kernel,
                        kv_spec=kv_spec, kv_kernel=kv_kernel)
    engine = ServeEngine(model, params, n_slots=sz.slots,
                         max_len=sz.long_prompt + sz.long_gen, chunk=sz.chunk,
                         seed=0)
    done = engine.run(_longctx_requests(cfg.vocab_size, sz))
    st = engine.stats()
    outs = {s.req.rid: list(s.out) for s in done}
    return st, outs


def _longctx_kv_spec(kv_quant: str):
    tok = (kv_quant or "").strip().lower()
    kv_spec = None if tok in ("none", "off") else parse_kv_spec(tok)
    if kv_spec is None:
        # "bf16"/"none" would run the bf16 cache three times and record it
        # under quantized-variant labels, polluting the perf trajectory
        raise ValueError(
            "the longctx mix measures the quantized KV cache: --kv-quant "
            f"must be a byte-wide fxp/pofx spec (e.g. fxp8, pofx8es2), "
            f"got {kv_quant!r}")
    return kv_spec


def run_longctx(cfg, params, kv_spec, use_kernel: bool, sz: Sizes = FULL):
    """Long-context arrival mix: bf16 cache vs quantized cache (XLA
    fallback and fused kernel). Returns (rows, claims)."""
    ctx_len = sz.long_prompt + sz.long_gen
    bf16 = kv_decode_bytes_per_token(cfg, ctx_len, None)
    rows, outs_by_variant = [], {}
    variants = [("bf16", None, False),
                ("xla-fallback", kv_spec, False),
                ("fused-kernel", kv_spec, True)]
    for name, spec, kern in variants:
        st, outs = _run_longctx(cfg, params, spec, kern, use_kernel, sz)
        if spec is not None:   # identity check is kernel-vs-fallback only
            outs_by_variant[name] = outs
        traffic = kv_decode_bytes_per_token(cfg, ctx_len, spec)
        rows.append({
            "mix": "longctx", "arch": ARCH, "quant": "(shared)",
            "use_kernel": use_kernel, "slots": sz.slots,
            "requests": sz.n_req,
            "prompt_len": sz.long_prompt, "gen": sz.long_gen,
            "generated_tokens": st["generated_tokens"],
            "decode_steps": st["decode_steps"],
            "decode_tok_per_s": round(
                st["decode_tokens"] / max(st["decode_time_s"], 1e-9), 2),
            "prefill_s": round(st["prefill_time_s"], 4),
            "decode_s": round(st["decode_time_s"], 4),
            "kv_variant": name,
            "kv_spec": format_spec(spec) if spec else "bf16",
            "kv_hbm_bytes_per_token": traffic["code_bytes"],
            "kv_scale_bytes_per_step": traffic["scale_bytes"],
        })
    quant_bytes = rows[1]["kv_hbm_bytes_per_token"]
    identical = outs_by_variant["xla-fallback"] == outs_by_variant["fused-kernel"]
    if not identical:
        # must be loud: the acceptance contract is token-identity between
        # the fused kernel and the quantize/dequantize fallback
        raise AssertionError(
            "kv flash-decode kernel and XLA fallback disagree under greedy "
            f"sampling: {outs_by_variant['fused-kernel']} vs "
            f"{outs_by_variant['xla-fallback']}")
    claims = {
        "kv_hbm_bytes_ratio": round(bf16["code_bytes"] / quant_bytes, 3),
        "kv_kernel_token_identical": identical,
    }
    return rows, claims


def run_sharedprefix(cfg, params, kv_spec, use_kernel: bool, sz: Sizes,
                     k_prompts: int = 2, page_size: int = 8):
    """Shared-system-prompt mix through the paged engine (DESIGN.md §10).

    N requests over K distinct system prompts (each 3/4 of the prompt
    length, so it spans whole pages plus a partial tail — the CoW path);
    the paged engine must skip the shared prefill, agree with the bench's
    own expected hit count, and stay token-identical to the dense engine.
    Returns (rows, claims).
    """
    rng = np.random.default_rng(11)
    sys_len = max(page_size, 3 * sz.prompt // 4)
    sys_prompts = [rng.integers(0, cfg.vocab_size, sys_len)
                   for _ in range(k_prompts)]
    prompts = []
    for i in range(sz.n_req):
        tail = rng.integers(0, cfg.vocab_size, max(1, sz.prompt - sys_len))
        prompts.append(np.concatenate([sys_prompts[i % k_prompts], tail]))
    # the last request repeats request 0's FULL prompt (an identical
    # retry): its index hit caps at context-1, which lands mid-page, so
    # the mix exercises the copy-on-write path too (asserted below);
    # distinct-tail requests share only whole system-prefix pages (the
    # radix index is page-granular on full pages)
    prompts[-1] = prompts[0]

    def reqs():
        return [Request(rid=i, prompt=prompts[i], max_new=sz.gen,
                        sampling=SamplingParams(),
                        arrival=float(i * (sz.gen // 2)))
                for i in range(sz.n_req)]

    workload = reqs()
    # f32 activations pin the dense-vs-paged identity assertion the same
    # way DESIGN.md §9/§10 pin the TP and sharing differentials: a
    # prefix-hit admission prefills only the suffix rows, and at bf16 the
    # different reduction tiling can flip a boundary-straddling token
    model = build_model(cfg, RunConfig(remat="none",
                                       activation_dtype="f32"),
                        use_kernel=use_kernel, kv_spec=kv_spec)
    max_len = sz.prompt + sz.gen
    dense = ServeEngine(model, params, n_slots=sz.slots, max_len=max_len,
                        chunk=sz.chunk, seed=0)
    ref = {s.req.rid: list(s.out) for s in dense.run([
        dataclasses.replace(r) for r in workload])}
    engine = ServeEngine(model, params, n_slots=sz.slots, max_len=max_len,
                         chunk=sz.chunk, seed=0, paged=True,
                         page_size=page_size)
    done = engine.run(workload)
    outs = {s.req.rid: list(s.out) for s in done}
    if outs != ref:
        raise AssertionError(
            f"paged engine diverges from dense on the sharedprefix mix: "
            f"{outs} vs {ref}")
    st = engine.stats()
    # admissions are serialized on the host, so every request after the
    # first of its prompt group must hit the index (>= the page-aligned
    # system prefix; CoW extends the hit into the shared partial page)
    expected_hits = sz.n_req - k_prompts
    if st["prefix_hits"] != expected_hits:
        raise AssertionError(
            f"prefix-cache hit count disagrees with the workload: engine "
            f"reports {st['prefix_hits']}, bench expects {expected_hits} "
            f"({sz.n_req} requests over {k_prompts} prompts)")
    # bytes of pool HBM per deduplicated resident token: per-token K+V
    # code bytes x page-internal fragmentation (allocated page slots over
    # distinct resident tokens) — the capacity half of the paging win
    per_tok = kv_decode_bytes_per_token(cfg, 1, kv_spec)["code_bytes"]
    resident = max(st["index_resident_tokens"], 1)
    row = {
        "mix": "sharedprefix", "arch": ARCH, "quant": "(shared)",
        "use_kernel": use_kernel, "slots": sz.slots,
        "requests": sz.n_req, "prompt_len": sz.prompt, "gen": sz.gen,
        "generated_tokens": st["generated_tokens"],
        "decode_steps": st["decode_steps"],
        "decode_tok_per_s": round(
            st["decode_tokens"] / max(st["decode_time_s"], 1e-9), 2),
        "prefill_s": round(st["prefill_time_s"], 4),
        "decode_s": round(st["decode_time_s"], 4),
        "kv_variant": f"paged-{page_size}",
        "kv_spec": format_spec(kv_spec) if kv_spec else "bf16",
        "prefill_tokens_skipped": st["prefix_hit_tokens"],
        "prefix_hit_rate": round(st["prefix_hit_rate"], 3),
        "resident_pages": st["resident_pages"],
        "kv_bytes_per_resident_token": round(
            per_tok * st["resident_pages"] * page_size / resident, 1),
        "cow_copies": st["cow_copies"],
    }
    claims = {
        "sharedprefix_prefill_tokens_skipped": int(st["prefix_hit_tokens"]),
        "sharedprefix_hits_agree": True,
        "sharedprefix_token_identical": True,
    }
    if st["prefix_hit_tokens"] <= 0:
        raise AssertionError(
            "sharedprefix mix skipped no prefill tokens: prefix sharing "
            "is not engaging")
    if st["cow_copies"] <= 0:
        raise AssertionError(
            "sharedprefix mix triggered no copy-on-write: the mis-aligned "
            "system prefix should end mid-page on every hit")
    return [row], claims


_TP_SMOKE_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
import sys; sys.path.insert(0, "src")
import numpy as np, jax
from repro.configs import ARCHS, RunConfig, smoke
from repro.launch.engine import Request, SamplingParams, ServeEngine
from repro.launch.mesh import make_tp_mesh
from repro.nn.models import build_model

cfg = smoke(ARCHS["yi-9b"])
rcfg = RunConfig(remat="none", activation_dtype="f32")
params = build_model(cfg, rcfg).init(jax.random.PRNGKey(0))
def reqs():
    return [Request(rid=i,
                    prompt=np.random.RandomState(i).randint(0, cfg.vocab_size, 8),
                    max_new=6, sampling=SamplingParams())
            for i in range(4)]
for tp in (1, 2):
    mesh = make_tp_mesh(tp) if tp > 1 else None
    eng = ServeEngine(build_model(cfg, rcfg, mesh=mesh), params,
                      n_slots=2, max_len=24, chunk=4)
    eng.run(reqs())                       # warmup: compile outside timing
    eng.prefill_time = eng.decode_time = 0.0
    eng.decode_steps = 0
    eng.clock = 0.0
    warm = eng.stats()["generated_tokens"]
    warm_sampled = eng.n_prefill_sampled
    done = eng.run([Request(rid=100 + r.rid, prompt=r.prompt,
                            max_new=r.max_new, sampling=r.sampling)
                    for r in reqs()])
    st = eng.stats()
    n_dec = (st["generated_tokens"] - warm) - (eng.n_prefill_sampled
                                               - warm_sampled)
    print(f"TPROW,{tp},{n_dec / max(st['decode_time_s'], 1e-9):.2f}")
"""


def run_tp_smoke():
    """tp=2 vs tp=1 decode tok/s (the ROADMAP bench-trajectory item).

    Runs in a child process with 2 fake CPU devices so the row exists even
    on single-device CI; on fake devices the ratio measures overhead, not
    speedup — the row's value is the *trajectory* (it fails loudly when TP
    serving bit-rots, and becomes a real comparison on multi-core
    runners). Returns (rows, claims).
    """
    import subprocess
    import sys
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "-c", _TP_SMOKE_CODE],
                       capture_output=True, text=True, cwd=root,
                       timeout=1200)
    if r.returncode != 0:
        raise RuntimeError(f"tp smoke subprocess failed:\n{r.stderr[-3000:]}")
    rates = {}
    for line in r.stdout.splitlines():
        if line.startswith("TPROW,"):
            _, tp, rate = line.split(",")
            rates[int(tp)] = float(rate)
    if sorted(rates) != [1, 2]:
        raise RuntimeError(f"tp smoke emitted {rates}, expected tp 1 and 2")
    rows = [{
        "mix": "tp-decode", "arch": ARCH, "quant": "none",
        "use_kernel": False, "slots": 2, "requests": 4,
        "prompt_len": 8, "gen": 6,
        "kv_variant": f"tp={tp}",
        "decode_tok_per_s": rate,
    } for tp, rate in sorted(rates.items())]
    claims = {
        "tp2_vs_tp1_decode_ratio": round(rates[2] / max(rates[1], 1e-9), 3),
    }
    return rows, claims


def run(use_kernel: bool = False, quant: str = "pofx8",
        kv_quant: str = "fxp8", smoke: bool = False):
    sz = SMOKE if smoke else FULL
    kv_spec = _longctx_kv_spec(kv_quant)   # fail fast, before engine work
    cfg = smoke_cfg(ARCHS[ARCH])
    model = build_model(cfg, RunConfig(remat="none"), use_kernel=use_kernel)
    params = apply_policy(model.init(jax.random.PRNGKey(0)), quant)
    rng = np.random.default_rng(7)
    rows = []
    for mix in ("burst", "staggered", "ragged"):
        reqs = _mix_requests(mix, cfg.vocab_size, sz)
        engine = ServeEngine(model, params, n_slots=sz.slots,
                             max_len=sz.prompt + sz.gen, chunk=sz.chunk,
                             seed=0)
        # warmup on the SAME engine (jit caches are per-instance): compile
        # prefill + the chunk variants outside the timed run, else the
        # first mix absorbs all XLA compile time and the mix comparison
        # becomes a measurement artifact
        engine.run([Request(rid=1000 + i,
                            prompt=rng.integers(0, cfg.vocab_size, sz.prompt),
                            max_new=sz.gen, sampling=SamplingParams())
                    for i in range(sz.slots)])
        engine.prefill_time = engine.decode_time = 0.0
        engine.decode_steps = 0
        engine.clock = 0.0  # warmup must not shift the measured arrivals
        warm_gen = engine.stats()["generated_tokens"]
        warm_sampled = engine.n_prefill_sampled
        engine.run(reqs)
        st = engine.stats()
        n_gen = st["generated_tokens"] - warm_gen
        n_dec = n_gen - (engine.n_prefill_sampled - warm_sampled)
        rows.append({
            "mix": mix, "arch": ARCH, "quant": quant,
            "use_kernel": use_kernel, "slots": sz.slots,
            "requests": sz.n_req,
            "prompt_len": sz.prompt, "gen": sz.gen,
            "generated_tokens": n_gen,
            "decode_steps": st["decode_steps"],
            "decode_tok_per_s": round(n_dec / max(st["decode_time_s"], 1e-9),
                                      2),
            "prefill_s": round(st["prefill_time_s"], 4),
            "decode_s": round(st["decode_time_s"], 4),
        })
    by_mix = {r["mix"]: r["decode_tok_per_s"] for r in rows}
    claims = {
        f"decode_tok_per_s[{m}]": v for m, v in by_mix.items()
    }
    claims["staggered_vs_burst_ratio"] = round(
        by_mix["staggered"] / max(by_mix["burst"], 1e-9), 3)
    # persist the arrival mixes before the longctx runs: the loud
    # kernel-vs-fallback identity assertion must not discard them
    write_csv("serve_engine", rows)
    long_rows, long_claims = run_longctx(cfg, params, kv_spec, use_kernel,
                                         sz)
    rows += long_rows
    claims.update(long_claims)
    write_csv("serve_engine", rows)
    sp_rows, sp_claims = run_sharedprefix(cfg, params, kv_spec, use_kernel,
                                          sz, page_size=2 if smoke else 8)
    rows += sp_rows
    claims.update(sp_claims)
    if smoke:
        # the ROADMAP bench-trajectory item: a tp=2-vs-tp=1 decode tok/s
        # datapoint, emitted from --smoke so the CI bit-rot run carries it
        tp_rows, tp_claims = run_tp_smoke()
        rows += tp_rows
        claims.update(tp_claims)
    write_csv("serve_engine", rows)
    return rows, claims


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--quant", default="pofx8")
    ap.add_argument("--kv-quant", default="fxp8",
                    help="KV-cache format for the longctx mix (fxp/pofx, "
                         "byte-wide codes)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes: bit-rot check, not a measurement")
    args = ap.parse_args(argv)
    rows, claims = run(use_kernel=args.use_kernel, quant=args.quant,
                       kv_quant=args.kv_quant, smoke=args.smoke)
    for r in rows:
        print(r)
    for k, v in claims.items():
        print(f"serve_engine,{k},{v}")


if __name__ == "__main__":
    main()

"""QuantSpec/QuantizedTensor tests: pytree behavior, composite paths,
storage accounting, FxP view for the int8 MAC path, error ordering
(the paper's headline Fig. 1 claim)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    QuantSpec,
    dequantize,
    fxp_view,
    fxp_quantize_np,
    fxp_dequantize_np,
    quantize,
    storage_bits,
)
from repro.core.analysis import weight_error
from proptest import Floats, given


def _weights(shape=(128, 64), scale=0.05, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.standard_normal(shape) * scale).astype(np.float32))


@pytest.mark.parametrize("spec", [
    QuantSpec(kind="fxp", M=8, F=7),
    QuantSpec(kind="posit", N=8, ES=2),
    QuantSpec(kind="pofx", N=8, ES=2, path="via_fxp"),
    QuantSpec(kind="pofx", N=8, ES=2, path="direct"),
    QuantSpec(kind="bf16"),
    QuantSpec(kind="fp32"),
])
def test_quantize_dequantize_bounded_error(spec):
    w = _weights()
    qt = quantize(w, spec, axis=-1)
    wq = dequantize(qt, jnp.float32)
    assert wq.shape == w.shape
    assert not bool(jnp.any(jnp.isnan(wq)))
    err = float(jnp.mean(jnp.abs(wq - w)))
    assert err < 5e-3, (spec, err)


def test_quantized_tensor_is_pytree():
    w = _weights((16, 8))
    qt = quantize(w, QuantSpec(kind="pofx", N=8, ES=2), axis=-1)
    leaves, treedef = jax.tree_util.tree_flatten(qt)
    assert len(leaves) == 2  # codes + scale
    qt2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert np.array_equal(np.asarray(qt2.codes), np.asarray(qt.codes))
    # flows through jit
    f = jax.jit(lambda q: dequantize(q, jnp.float32).sum())
    assert np.isfinite(float(f(qt)))


def test_paper_fig1_error_ordering():
    """Posit(8,2) beats FxP8 on clustered-near-zero weights (Fig. 1: 0.052
    vs 0.295 average absolute relative error). We check the ordering and a
    >3x gap on a matched distribution (zero-mean, sigma=0.05, range +-0.3),
    using the paper's 'no normalizer' assumption (scale_mode='none')."""
    rng = np.random.default_rng(42)
    w = jnp.asarray(np.clip(rng.standard_normal(20000) * 0.05, -0.3, 0.3).astype(np.float32))
    e_fxp = weight_error(w, QuantSpec(kind="fxp", M=8, F=7, scale_mode="none"))
    e_pos = weight_error(w, QuantSpec(kind="posit", N=8, ES=2, scale_mode="none"))
    assert e_pos["avg_rel"] * 3 < e_fxp["avg_rel"], (e_pos, e_fxp)


def test_storage_bits_accounting():
    w = _weights((100, 10))
    bits = {
        "fp32": 32, "bf16": 16,
    }
    for kind, expect in bits.items():
        qt = quantize(w, QuantSpec(kind=kind))
        assert storage_bits(qt) == 1000 * expect
    # pofx stores N-1 bits/code + fp32 scales (per output channel = 10)
    qt = quantize(w, QuantSpec(kind="pofx", N=8, ES=2, scale_mode="channel_pow2"), axis=-1)
    assert storage_bits(qt) == 1000 * 7 + 10 * 32
    # paper claim: vs FxP8 the code storage is (8-7)/8 = 12.5% smaller;
    # vs FP32 it is 78% smaller
    qt8 = quantize(w, QuantSpec(kind="fxp", M=8, F=7, scale_mode="channel_pow2"), axis=-1)
    assert (storage_bits(qt8) - storage_bits(qt)) / storage_bits(qt8) == pytest.approx(0.125, abs=0.01)


def test_fxp_view_int8_path():
    """The int8 MXU view must reproduce dequantize() exactly."""
    w = _weights((32, 16))
    for spec in [QuantSpec(kind="fxp", M=8, F=7), QuantSpec(kind="pofx", N=8, ES=2)]:
        qt = quantize(w, spec, axis=-1)
        codes, rescale = fxp_view(qt)
        assert codes.dtype == jnp.int8
        recon = codes.astype(jnp.float32) * rescale
        ref = dequantize(qt, jnp.float32)
        np.testing.assert_allclose(np.asarray(recon), np.asarray(ref), rtol=0, atol=0)


def test_table5_path_ordering_mechanism():
    """FxP->Posit->FxP must round-trip FxP-representable weights much better
    than the direct Posit->FxP path (truncation bias) — the mechanism behind
    Table 5's accuracy collapse of Posit_FxP."""
    rng = np.random.default_rng(3)
    w_f = fxp_dequantize_np(fxp_quantize_np(rng.standard_normal(8192) * 0.2, 8, 7), 7)
    w = jnp.asarray(w_f.astype(np.float32))
    direct = quantize(w, QuantSpec(kind="pofx", N=8, ES=2, path="direct", scale_mode="none"))
    via = quantize(w, QuantSpec(kind="pofx", N=8, ES=2, path="via_fxp", scale_mode="none"))
    e_direct = float(jnp.mean(jnp.abs(dequantize(direct, jnp.float32) - w)))
    e_via = float(jnp.mean(jnp.abs(dequantize(via, jnp.float32) - w)))
    assert e_via <= e_direct


@given(seed=11, examples=25, x=Floats(lo=-4, hi=4, shape=(512,)))
def test_property_dequantize_within_lattice_gap(x):
    """Property: pofx dequantized values never exceed the normalizer range
    and error is bounded by the local lattice gap + truncation ulp."""
    w = jnp.asarray(x.astype(np.float32))
    spec = QuantSpec(kind="pofx", N=8, ES=2, scale_mode="tensor_pow2")
    qt = quantize(w, spec)
    wq = np.asarray(dequantize(qt, jnp.float32))
    scale = float(np.asarray(qt.scale).reshape(-1)[0])
    assert np.all(np.abs(wq) <= scale)
    # error bounded by (coarsest normalized gap + fxp ulp) * scale
    gap = (0.25 + 2 ** -7) * scale
    assert np.all(np.abs(wq - x) <= gap + 1e-6)

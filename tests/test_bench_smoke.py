"""Benchmark bit-rot guard: every entry registered in benchmarks.run must
import and run in --smoke mode inside CI.

Before this test, a bench that drifted out of sync with a refactor (an
import, a renamed kwarg, a changed claim key) only failed at
paper-figure-generation time. Each bench runs with the same kwargs
``benchmarks.run --smoke`` would pass it, must return (rows, claims), and
its claims must be printable scalars (the ``bench,claim,value`` contract
EXPERIMENTS.md is generated from). CSV writes are redirected to a tmp dir
via REPRO_BENCH_OUT so smoke-sized rows never clobber the committed
experiments/bench artifacts.
"""
import inspect

import numpy as np
import pytest

from benchmarks.run import BENCHES


@pytest.mark.parametrize("name,module", BENCHES, ids=[b[0] for b in BENCHES])
def test_bench_runs_in_smoke_mode(name, module, tmp_path, monkeypatch):
    # smoke rows must not clobber the committed experiments/bench CSVs
    monkeypatch.setenv("REPRO_BENCH_OUT", str(tmp_path))
    mod = __import__(module, fromlist=["run"])
    sig = inspect.signature(mod.run).parameters
    kwargs = {}
    if "smoke" in sig:
        kwargs["smoke"] = True
    if "extra_specs" in sig:
        kwargs["extra_specs"] = ()
    rows, claims = mod.run(**kwargs)
    assert isinstance(rows, list)
    assert isinstance(claims, dict) and claims, f"{name}: no claims emitted"
    for key, val in claims.items():
        assert isinstance(key, str)
        # the harness prints claims as CSV "bench,claim,value" lines
        assert isinstance(val, (bool, int, float, str, np.bool_,
                                np.integer, np.floating, dict)), (
            f"{name}: claim {key!r} has unprintable type {type(val)}")

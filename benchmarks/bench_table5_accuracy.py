"""Table 5: end-task accuracy per quantization configuration.

The paper quantizes pre-trained VGG16 and measures ImageNet top-1/top-5.
Offline-container analogue: train a real MLP classifier on a deterministic
synthetic task to convergence (fp32), then post-training-quantize its
weights with every scheme and re-measure accuracy — including the paper's
two PoFx paths, whose ORDERING is the key Table-5 claim:

    Posit_FxP       (direct:  fp32 -> posit -> FxP)       degrades badly
    FxP_Posit_FxP   (via_fxp: fp32 -> FxP -> posit -> FxP) nearly lossless
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.analysis import spec_name
from repro.core.policy import parse_spec
from repro.core.quantizers import dequantize, quantize

from .common import write_csv


def _task(n=4096, d=32, classes=10, seed=0):
    """Hard-margin gaussian mixture: fp32 test accuracy lands ~0.9 so
    quantization damage is measurable (centers overlap)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(classes, d)) * 0.55
    y = rng.integers(0, classes, n)
    x = centers[y] + rng.normal(size=(n, d))
    return jnp.asarray(x, jnp.float32), jnp.asarray(y)


def _train_mlp(x, y, classes, hidden=64, steps=300, lr=3e-2, seed=0):
    d = x.shape[1]
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    params = {
        "w1": jax.random.normal(ks[0], (d, hidden)) * d ** -0.5,
        "w2": jax.random.normal(ks[1], (hidden, hidden)) * hidden ** -0.5,
        "w3": jax.random.normal(ks[2], (hidden, classes)) * hidden ** -0.5,
    }

    def fwd(p, x):
        h = jax.nn.relu(x @ p["w1"])
        h = jax.nn.relu(h @ p["w2"])
        return h @ p["w3"]

    def loss(p):
        lg = fwd(p, x)
        return -jnp.mean(jax.nn.log_softmax(lg)[jnp.arange(x.shape[0]), y])

    @jax.jit
    def step(p):
        g = jax.grad(loss)(p)
        return jax.tree.map(lambda a, b: a - lr * b, p, g)

    for _ in range(steps):
        params = step(params)
    return params, fwd


def _accuracy(fwd, params, x, y) -> float:
    pred = jnp.argmax(fwd(params, x), axis=-1)
    return float(jnp.mean(pred == y))


def run(extra_specs=(), smoke: bool = False):
    # smoke: smaller task + shorter training — the config sweep and every
    # claim key still compute, just on a weaker (still converged) MLP
    x, y = _task(n=1024 if smoke else 4096)
    n_tr = 768 if smoke else 3072
    params, fwd = _train_mlp(x[:n_tr], y[:n_tr], 10,
                             steps=80 if smoke else 300)
    xte, yte = x[n_tr:], y[n_tr:]
    base_acc = _accuracy(fwd, params, xte, yte)

    def quantized_acc(spec):
        qp = {k: quantize(v, spec, axis=-1) for k, v in params.items()}
        qp = {k: dequantize(v, jnp.float32) for k, v in qp.items()}
        return _accuracy(fwd, qp, xte, yte)

    rows = [{"config": "fp32", "accuracy": base_acc, "drop": 0.0}]
    spec_strings = ["fxp16", "fxp8", "fxp7", "fxp4"]
    spec_strings += [f"posit{N}es{ES}" for N in (6, 7, 8) for ES in (1, 2, 3)]
    for N in (6, 7, 8):
        for ES in (1, 2):
            spec_strings += [f"pofx{N}es{ES}-direct", f"pofx{N}es{ES}"]
    spec_strings += list(extra_specs)
    for spec in map(parse_spec, spec_strings):
        name = spec_name(spec)
        acc = quantized_acc(spec)
        rows.append({"config": name, "accuracy": acc,
                     "drop": base_acc - acc})
    write_csv("table5_accuracy", rows)
    by = {r["config"]: r["accuracy"] for r in rows}
    via = np.mean([by[f"pofx({n},{e},via_fxp)"] for n in (5, 6, 7)
                   for e in (1, 2)])
    direct = np.mean([by[f"pofx({n},{e},direct)"] for n in (5, 6, 7)
                      for e in (1, 2)])
    # REPRODUCTION FINDING (EXPERIMENTS.md §Claims, claim 2): the paper's
    # Table 5 shows the direct Posit->FxP path COLLAPSING accuracy (1.9-46%
    # top-1) while FxP->Posit->FxP preserves it. In this bias-free
    # reimplementation both paths are near-lossless and within ~10% of each
    # other in weight error — a bounded <=1-ulp perturbation mathematically
    # cannot collapse accuracy. We attribute the paper's direct-path
    # numbers to a flow artifact (likely unclamped/mis-scaled conversion);
    # our Algorithm-1-faithful PoFx makes BOTH deployment paths safe, which
    # strengthens the technique.
    werr = {}
    for path in ("direct", "via_fxp"):
        spec = parse_spec("pofx7es2-direct" if path == "direct" else "pofx7es2")
        errs = []
        for v in params.values():
            wq = dequantize(quantize(v, spec, axis=-1), jnp.float32)
            errs.append(float(jnp.mean(jnp.abs(wq - v))))
        werr[path] = float(np.mean(errs))
    return rows, {
        "fp32_acc": base_acc,
        "posit82_drop": base_acc - by["posit(8,2)"],
        "fxp8_drop": base_acc - by["fxp8"],
        "fxp4_drop": base_acc - by["fxp4"],
        "mean_acc_via_fxp": float(via),
        "mean_acc_direct": float(direct),
        "weight_err_direct": werr["direct"],
        "weight_err_via_fxp": werr["via_fxp"],
        "claim_posit8_near_lossless": (base_acc - by["posit(8,2)"]) < 0.02,
        "finding_direct_path_not_catastrophic":
            (base_acc - float(direct)) < 0.02,
        "finding_paths_within_10pct_weight_err":
            abs(werr["direct"] - werr["via_fxp"])
            <= 0.1 * max(werr.values()),
    }

"""Runtime: checkpoint atomicity/resume/compression, straggler monitor,
posit-compressed gradient mean, data pipeline determinism."""
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizers import QuantSpec
from repro.data import DataConfig, TokenFileReader, synthetic_batch, write_token_file
from repro.runtime import CheckpointManager, StepTimeMonitor
from repro.runtime.compression import posit_compressed_mean


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (16, 8)) * 0.1,
                       "b": jnp.zeros((8,), jnp.bfloat16)},
            "opt": {"count": jnp.asarray(7, jnp.int32)}}


def test_checkpoint_roundtrip_exact(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    state = _state()
    cm.save(5, state)
    got = cm.restore()
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_keep_k_and_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 3, 9):
        cm.save(s, _state())
    assert cm.all_steps() == [3, 9]
    assert cm.latest_step() == 9


def test_checkpoint_posit_compression_bounds_error(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=1, async_save=False)
    state = {"params": {"w": jnp.linspace(-1.0, 1.0, 256).reshape(16, 16)},
             "step": jnp.asarray(3)}
    spec = QuantSpec(kind="pofx", N=8, ES=2)
    cm.save(1, state, param_compress=spec)
    got = cm.restore()
    w0 = np.asarray(state["params"]["w"])
    w1 = np.asarray(got["params"]["w"])
    # posit(8,2) on [-1,1]: relative error ~2^-4 worst case near 1
    assert np.max(np.abs(w0 - w1)) < 0.07
    # and the stored file is actually ~7/32 the raw size
    root = os.path.join(str(tmp_path), "step_00000001")
    packed = os.path.getsize(os.path.join(root, "leaf_00000.npy"))
    assert packed < 256 * 4 * 0.3 + 200


def test_checkpoint_crash_mid_save_keeps_previous(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    cm.save(1, _state())
    # simulate a crashed save: stray tmp dir with garbage
    os.makedirs(os.path.join(str(tmp_path), ".tmp_00000002"))
    with open(os.path.join(str(tmp_path), ".tmp_00000002", "junk"), "w") as f:
        f.write("partial")
    assert cm.latest_step() == 1
    got = cm.restore()
    assert int(got["opt"]["count"]) == 7


def test_checkpoint_async_is_consistent(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    state = _state()
    cm.save(1, state)
    # mutate after save() returns: snapshot must not see it
    state["params"]["w"] = state["params"]["w"] * 0
    cm.wait()
    got = cm.restore()
    assert float(jnp.abs(jnp.asarray(got["params"]["w"])).max()) > 0


def test_straggler_monitor_flags_and_restart():
    mon = StepTimeMonitor(warmup=4, z_threshold=4.0, abort_ratio=2.0)
    for i in range(8):
        assert mon.record(i, 0.1) is None
    ev = mon.record(8, 0.5)
    assert ev is not None and ev.zscore > 4
    assert not mon.should_restart()
    for i in range(9, 12):
        mon.record(i, 0.5)
    assert mon.should_restart()


def test_data_determinism_and_shift():
    dc = DataConfig(vocab_size=128, seq_len=32, global_batch=4)
    a, b = synthetic_batch(dc, 11), synthetic_batch(dc, 11)
    assert np.array_equal(a["tokens"], b["tokens"])
    assert np.array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    c = synthetic_batch(dc, 12)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_host_sharding_partitions():
    dc = DataConfig(vocab_size=128, seq_len=16, global_batch=8)
    shards = [synthetic_batch(dc, 0, host_id=h, n_hosts=4) for h in range(4)]
    assert all(s["tokens"].shape == (2, 16) for s in shards)
    flat = {tuple(r) for s in shards for r in s["tokens"]}
    assert len(flat) >= 7  # shards are (near-surely) distinct


def test_token_file_reader(tmp_path):
    path = str(tmp_path / "tok.bin")
    toks = np.arange(5000) % 70000  # forces uint32
    write_token_file(path, toks)
    r = TokenFileReader(path, seq_len=64, batch=4)
    b0 = r.read_batch(0)
    b0_again = r.read_batch(0)
    assert np.array_equal(b0["tokens"], b0_again["tokens"])
    assert np.array_equal(b0["tokens"][:, 1:], b0["labels"][:, :-1])
    # windows advance deterministically with step
    b1 = r.read_batch(1)
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_posit_compressed_mean_single_axis_error_bound():
    """Without a mesh: encode/decode roundtrip accuracy of the transport."""
    from repro.core.normalized_posit import norm_decode, norm_encode
    x = jax.random.normal(jax.random.PRNGKey(0), (512,)) * 1e-3
    scale = jnp.exp2(jnp.ceil(jnp.log2(jnp.max(jnp.abs(x)))))
    codes = norm_encode(x / scale, 8, 2)
    back = norm_decode(codes, 8, 2) * scale
    rel = float(jnp.max(jnp.abs(back - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.05

"""Production mesh construction (functions only — importing this module
never touches jax device state).

Single pod: 256 chips as (16, 16) ("data", "model").
Multi pod:  2 pods x 256 chips as (2, 16, 16) ("pod", "data", "model");
the "pod" axis crosses DCN — gradient all-reduce (optionally posit8-
compressed, runtime/compression.py) is the only traffic on it.
Serving:    a 1-D ("tp",) mesh for the tensor-parallel engine
(DESIGN.md §9); CPU CI fakes devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
"""
from __future__ import annotations

from typing import Optional

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "make_tp_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1,
                   devices=None):
    """Small mesh over whatever devices exist (tests / examples)."""
    shape = (pod, data, model) if pod > 1 else (data, model)
    axes = ("pod", "data", "model") if pod > 1 else ("data", "model")
    return jax.make_mesh(shape, axes, devices=devices)


def make_tp_mesh(tp: int, devices=None):
    """1-D ("tp",) serving mesh for the tensor-parallel engine.

    Uses the first ``tp`` local devices when ``devices`` is not given, so
    a tp smaller than the device count works (the differential tests run
    tp in {1, 2, 4} against one forced-4-device process).
    """
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if devices is None:
        avail = jax.devices()
        if len(avail) < tp:
            raise ValueError(
                f"tp={tp} needs {tp} devices but only {len(avail)} exist "
                "(CPU: set XLA_FLAGS=--xla_force_host_platform_device_count)")
        devices = avail[:tp]
    return jax.make_mesh((tp,), ("tp",), devices=devices)

"""Pallas TPU kernel: fused PoFx decode + matmul — the Move&Store datapath.

This is the paper's PoFx(Move & Store) accelerator (Fig. 20, design 3) mapped
onto the TPU memory hierarchy:

    HBM:   W stored as uint8 normalized-posit codes  ((N-1)/16 of bf16 bytes)
    VMEM:  per-(k,j) tile decoded on the VPU (bit-level Algorithm 1), then
    MXU:   bf16/f32 dot against the activation tile, f32 accumulation in a
           VMEM scratch accumulator across the k grid dimension.

Decode modes:
  "bitlevel" — Algorithm 1 stages as lane-wise int32 ops (faithful port);
  "onehot"   — 2^(N-1)-entry LUT realized as one-hot @ table matmul, i.e. the
               decode itself runs on the MXU (TPU-idiomatic alternative; the
               §Perf log compares both).

Weight HBM traffic per step drops to (N-1 bits)/weight vs 16 (bf16) — this is
the paper's storage/communication reduction re-expressed as the memory-
roofline term that dominates TPU decode workloads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.pofx import pofx_norm_lut
from . import default_blocks, vmem_scratch
from .ref import decode_norm_to_fxp

__all__ = ["pofx_matmul"]


def _kernel(x_ref, w_ref, s_ref, lut_ref, o_ref, acc_ref, *, N, ES, M, nk, decode_mode):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = w_ref[...].astype(jnp.int32)
    inv = 1.0 / (1 << (M - 1))
    if decode_mode == "onehot":
        # One-hot matmul against the LUT: decode on the MXU. codes tile
        # (bk, bn) -> one-hot against the 2^(N-1)-entry value table.
        depth = 1 << (N - 1)
        oh = (codes[..., None] == jax.lax.broadcasted_iota(jnp.int32, (1, 1, depth), 2))
        vals = lut_ref[...].astype(jnp.float32) * inv  # (1, depth)
        w = jnp.sum(oh.astype(jnp.float32) * vals[0], axis=-1)
    else:
        fxp = decode_norm_to_fxp(codes, N, ES, M)
        w = fxp.astype(jnp.float32) * inv
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("N", "ES", "M", "blocks", "decode_mode",
                                             "interpret", "out_dtype"))
def pofx_matmul(x: jax.Array, codes: jax.Array, scale: jax.Array,
                N: int, ES: int, M: int = 8, blocks=None,
                decode_mode: str = "bitlevel", interpret: bool | None = None,
                out_dtype=jnp.float32) -> jax.Array:
    """x:(m,k) @ decode(codes:(k,n)) * scale:(n,) -> (m,n)."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    if blocks is None:
        blocks = default_blocks()
    m, kdim = x.shape
    k2, n = codes.shape
    if kdim != k2:
        # A real error, not a bare assert: this check guards the public
        # kernel entry and must survive `python -O`.
        raise ValueError(
            f"contraction mismatch: x {x.shape} @ codes {codes.shape}")
    bm, bn, bk = (min(blocks[0], m), min(blocks[1], n), min(blocks[2], kdim))
    pm, pn, pk = (-m) % bm, (-n) % bn, (-kdim) % bk
    xp = jnp.pad(x, ((0, pm), (0, pk)))
    cp = jnp.pad(codes, ((0, pk), (0, pn)))  # code 0 decodes to 0 -> safe pad
    sp = jnp.pad(jnp.reshape(scale, (1, -1)).astype(jnp.float32), ((0, 0), (0, pn)))
    grid = (xp.shape[0] // bm, cp.shape[1] // bn, xp.shape[1] // bk)
    depth = 1 << (N - 1)
    lut = jnp.asarray(pofx_norm_lut(N, ES, M), jnp.int32).reshape(1, depth)
    out = pl.pallas_call(
        functools.partial(_kernel, N=N, ES=ES, M=M, nk=grid[2],
                          decode_mode=decode_mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, depth), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], cp.shape[1]), out_dtype),
        scratch_shapes=[vmem_scratch((bm, bn))],
        interpret=interpret,
    )(xp, cp, sp, lut)
    return out[:m, :n]

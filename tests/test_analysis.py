"""Behavioral analysis (Fig. 8) and Pareto/hypervolume (Tables 3/4) tests."""
import numpy as np
import jax.numpy as jnp

from repro.core import QuantSpec, hypervolume, hypervolume_gain, pareto_front, pareto_mask
from repro.core.analysis import default_spec_grid, sweep_configs, weight_error


def test_pareto_mask_basic():
    pts = np.array([[1.0, 1.0], [2.0, 2.0], [0.5, 3.0], [3.0, 0.5], [1.0, 1.0]])
    mask = pareto_mask(pts)
    assert mask[0] and mask[2] and mask[3]
    assert not mask[1]


def test_hypervolume_2d_exact():
    pts = np.array([[1.0, 2.0], [2.0, 1.0]])
    ref = np.array([3.0, 3.0])
    # union of two rectangles: 2*1 + 1*2 - 1*1 = 3
    assert hypervolume(pts, ref) == 3.0


def test_hypervolume_3d_exact():
    pts = np.array([[1.0, 1.0, 1.0]])
    ref = np.array([2.0, 3.0, 4.0])
    assert hypervolume(pts, ref) == 1.0 * 2.0 * 3.0


def test_hypervolume_gain_positive_when_dominating():
    base = np.array([[2.0, 2.0]])
    extra = np.array([[1.0, 1.0]])
    g = hypervolume_gain(base, extra, np.array([3.0, 3.0]))
    assert g > 0


def test_sweep_prunes_and_ranks():
    rng = np.random.default_rng(0)
    weights = {
        "fc1": jnp.asarray((rng.standard_normal((64, 32)) * 0.08).astype(np.float32)),
        "fc2": jnp.asarray((rng.standard_normal((32, 10)) * 0.2).astype(np.float32)),
    }
    x = jnp.asarray(rng.standard_normal((4, 64)).astype(np.float32))
    layer_apply = {"fc1": (lambda w, x_: x_ @ w, x)}
    specs = [QuantSpec(kind="fxp", M=8, F=7),
             QuantSpec(kind="posit", N=8, ES=2),
             QuantSpec(kind="posit", N=4, ES=3),   # terrible -> pruned at (a)
             QuantSpec(kind="pofx", N=8, ES=2)]
    rep = sweep_configs(weights, specs, layer_apply=layer_apply,
                        end_to_end=lambda s: 1.0, prune_weight_err=0.3)
    assert "posit(4,3)" in rep.pruned_at_a
    assert "pofx(7,2,via_fxp)" in rep.survivors
    assert "metric" in rep.per_config["fxp8"]
    assert "config," in rep.table()


def test_default_grid_covers_paper_sweep():
    names = {s.kind for s in default_spec_grid()}
    assert names == {"fxp", "posit", "pofx"}
    assert len(default_spec_grid()) > 20


def test_weight_error_monotone_in_bits():
    """More posit bits -> lower quantization error (sanity)."""
    rng = np.random.default_rng(5)
    w = jnp.asarray((rng.standard_normal(4096) * 0.1).astype(np.float32))
    errs = [weight_error(w, QuantSpec(kind="posit", N=N, ES=1))["avg_rel"]
            for N in (5, 6, 7, 8)]
    assert all(a > b for a, b in zip(errs, errs[1:]))

"""Quantized KV cache subsystem tests (DESIGN.md §8).

Covers the ``kv=`` policy rule class, the 4D code/scale quantize helpers,
cache allocation (code+scale leaves, logical-axis agreement), the
model-level kernel-vs-XLA-fallback equivalence, and — the load-bearing
engine guarantees — evict -> re-prefill resume bit-identity under a lossy
cache and greedy token-identity between the fused flash-decode kernel and
the quantize-on-write/dequantize-on-read fallback.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from differential import (assert_token_identical, differential_engines,
                          make_engine, make_prompt as _prompt,
                          make_request as _req)
from proptest import Choice, Floats, given
from repro.configs import ARCHS, RunConfig, smoke
from repro.core.policy import (PRESETS, QuantPolicy, format_spec,
                               parse_kv_spec, resolve_kv_spec)
from repro.core.quantizers import (QuantSpec, kv_code_dtype, kv_dequantize,
                                   kv_quantize, validate_kv_spec)
from repro.launch.engine import ServeEngine
from repro.nn.models import build_model, kv_decode_bytes_per_token

FXP8 = QuantSpec(kind="fxp", M=8, F=7)
POFX8 = QuantSpec(kind="pofx", N=8, ES=2)


@pytest.fixture(scope="module")
def dense_parts(tiny):
    cfg, model, params = tiny("yi-9b")
    return cfg, model.rcfg, params


def _model(cfg, rcfg, kv_spec=None, kv_kernel=None, use_kernel=False):
    return build_model(cfg, rcfg, use_kernel=use_kernel, kv_spec=kv_spec,
                       kv_kernel=kv_kernel)


# ---------------------------------------------------------------------------
# Policy grammar: the kv= rule class
# ---------------------------------------------------------------------------


def test_kv_rule_parse_and_roundtrip():
    pol = QuantPolicy.from_string("attn/*=pofx8es2,kv=fxp8,*=bf16")
    assert pol.kv_spec == FXP8
    assert "kv=fxp8" in pol.to_string()
    assert QuantPolicy.from_string(pol.to_string()).kv_spec == FXP8
    # pofx spec + default: no kv rule -> None
    assert QuantPolicy.from_string("kv=pofx8es2").kv_spec == POFX8
    assert QuantPolicy.from_string("*=pofx8es2").kv_spec is None


def test_kv_rule_never_matches_parameter_paths():
    pol = QuantPolicy.from_string("kv=fxp8,*=pofx8es2")
    # even a parameter path literally named kv must not hit the kv rule
    for name in ("blocks/attn/wq", "kv", "blocks/kv"):
        rule = pol.match_rule(name)
        assert rule is not None and rule[0] == "*"


def test_kv_rule_validation():
    with pytest.raises(ValueError, match="fxp or pofx"):
        QuantPolicy.from_string("kv=posit8es2")
    with pytest.raises(ValueError, match="byte-wide"):
        QuantPolicy.from_string("kv=fxp16")
    with pytest.raises(ValueError, match="duplicate"):
        QuantPolicy.from_string("kv=fxp8,kv=pofx8es2")
    # bf16/fp32/keep normalize to "unquantized"
    assert QuantPolicy.from_string("kv=bf16,*=pofx8es2").kv_spec is None
    assert validate_kv_spec(None) is None
    assert validate_kv_spec(QuantSpec(kind="bf16")) is None


def test_kv_preset_and_resolve():
    pol = QuantPolicy.from_string("paper-table6-kv8")
    assert pol.kv_spec == FXP8
    assert format_spec(pol.match("embed")) == "bf16"  # embed rule applies
    assert resolve_kv_spec("auto", pol) == FXP8
    assert resolve_kv_spec("none", pol) is None
    assert resolve_kv_spec("pofx8es2", pol) == POFX8
    assert "paper-table6-kv8" in PRESETS


# ---------------------------------------------------------------------------
# 4D quantize/dequantize helpers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", [FXP8, QuantSpec(kind="fxp", M=8, F=4),
                                  POFX8, QuantSpec(kind="pofx", N=6, ES=1)])
def test_kv_quantize_4d_roundtrip(spec):
    rng = np.random.default_rng(0)
    # keep |x/scale| < 0.9: inside every tested format's exactly-covered
    # range, so the roundtrip error is grid-sized, not saturation-sized
    x = jnp.asarray(rng.uniform(-0.9, 0.9, (2, 3, 5, 16)), jnp.float32)
    scale = jnp.asarray(np.exp2(rng.integers(0, 2, (2, 3, 1, 16))),
                        jnp.float32)
    codes = kv_quantize(x * scale, spec, scale)
    assert codes.dtype == kv_code_dtype(spec)
    assert codes.shape == x.shape
    y = kv_dequantize(codes, spec, scale) / scale
    # coarsest step among the tested formats: fxp8f4 -> 2^-4; pofx(6,1)
    # tapers to ~2^-3 ulps near |1| — grid-sized, not layout-bug-sized
    assert float(jnp.abs(y - x).max()) < 0.2
    assert float(jnp.abs(y - x).mean()) < 0.05
    # determinism: same floats -> same codes (the resume contract)
    np.testing.assert_array_equal(
        np.asarray(codes), np.asarray(kv_quantize(x * scale, spec, scale)))


def test_kv_quantize_rejects_non_code_kinds():
    with pytest.raises(ValueError, match="kv code path"):
        kv_quantize(jnp.ones((2, 2)), QuantSpec(kind="posit", N=8, ES=2), 1.0)
    with pytest.raises(ValueError, match="decode path"):
        kv_dequantize(jnp.ones((2, 2), jnp.int8), QuantSpec(kind="bf16"), 1.0)


# ---------------------------------------------------------------------------
# Cache allocation and logical axes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["yi-9b", "moonshot-v1-16b-a3b",
                                  "zamba2-1.2b"])
def test_init_cache_code_and_scale_leaves(arch):
    cfg = smoke(ARCHS[arch])
    model = _model(cfg, RunConfig(remat="none"), kv_spec=FXP8)
    cache = model.init_cache(2, 16)
    kv = cache["kv"]["moe"] if cfg.family == "moe" else (
        cache["shared_kv"] if cfg.family == "hybrid" else cache["kv"])
    assert kv["k"].dtype == jnp.int8 and kv["v"].dtype == jnp.int8
    assert kv["k_scale"].dtype == jnp.float32
    assert kv["k_scale"].shape[-2:] == (1, cfg.d_head)
    # cache and cache_logical must agree leaf-for-leaf (the engine scatter
    # zips them positionally)
    n = len(jax.tree_util.tree_leaves(cache))
    log = jax.tree_util.tree_flatten(model.cache_logical(),
                                     is_leaf=lambda x: isinstance(x, tuple))[0]
    # hybrid/moe caches may be larger than the logical template only if
    # the template covers every leaf 1:1
    assert n == len(log)


def test_init_cache_kv_spec_override(dense_parts):
    cfg, rcfg, params = dense_parts
    model = _model(cfg, rcfg)             # model default: unquantized
    cache = model.init_cache(1, 8, kv_spec=FXP8)
    assert cache["kv"]["k"].dtype == jnp.int8
    model_q = _model(cfg, rcfg, kv_spec=POFX8)
    assert model_q.init_cache(1, 8)["kv"]["k"].dtype == jnp.uint8
    assert model_q.init_cache(1, 8, kv_spec=None)["kv"]["k"].dtype == jnp.bfloat16
    # the override is allocation-only: consuming a cache whose layout
    # disagrees with the model's kv_spec must fail loudly, not silently
    # astype float K/V into the int8 code leaves
    toks = jnp.zeros((1, 4), jnp.int32)
    with pytest.raises(ValueError, match="disagrees"):
        model.prefill(params, toks, cache=cache)
    with pytest.raises(ValueError, match="disagrees"):
        model_q.prefill(params, toks, cache=model_q.init_cache(1, 8, kv_spec=None))
    with pytest.raises(ValueError, match="code dtype"):
        model_q.decode_step(params, model_q.init_cache(1, 8, kv_spec=FXP8),
                            jnp.zeros((1, 1), jnp.int32))


def test_validate_kv_spec_rejects_nontrunc_pofx_rounding():
    # the kernel's bit-level VPU decode truncates; a nearest-rounding pofx
    # spec would make kernel and XLA fallback silently disagree per code
    with pytest.raises(ValueError, match="trunc"):
        validate_kv_spec(QuantSpec(kind="pofx", N=8, ES=2, rounding="nearest"))


def test_init_cache_rejects_encdec_kv_quant():
    cfg = smoke(ARCHS["whisper-medium"])
    model = _model(cfg, RunConfig(remat="none"), kv_spec=FXP8)
    with pytest.raises(ValueError, match="encdec"):
        model.init_cache(1, 16)


def test_kv_decode_bytes_per_token_model():
    cfg = smoke(ARCHS["yi-9b"])
    bf16 = kv_decode_bytes_per_token(cfg, 128, None)
    q = kv_decode_bytes_per_token(cfg, 128, FXP8)
    assert bf16["code_bytes"] == 2 * q["code_bytes"]  # 2 bytes -> 1 byte
    assert bf16["scale_bytes"] == 0 and q["scale_bytes"] > 0
    # S-proportional: doubling context doubles the code term only
    q2 = kv_decode_bytes_per_token(cfg, 256, FXP8)
    assert q2["code_bytes"] == 2 * q["code_bytes"]
    assert q2["scale_bytes"] == q["scale_bytes"]
    assert kv_decode_bytes_per_token(
        smoke(ARCHS["falcon-mamba-7b"]), 128, FXP8)["code_bytes"] == 0


# ---------------------------------------------------------------------------
# Model level: prefill+decode through codes; kernel == XLA fallback
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", [FXP8, POFX8])
def test_decode_kernel_matches_xla_fallback(dense_parts, spec):
    cfg, rcfg, params = dense_parts
    toks = jnp.asarray(_prompt(0, 6, cfg.vocab_size))[None]
    logits = {}
    for kern in (False, True):
        model = _model(cfg, rcfg, kv_spec=spec, kv_kernel=kern)
        cache = model.init_cache(1, 16)
        cache, lg = model.prefill(params, toks, cache=cache)
        for _ in range(3):
            cache, lg = model.decode_step(params, cache,
                                          jnp.argmax(lg, -1)[:, None])
        logits[kern] = np.asarray(lg, np.float32)
    np.testing.assert_allclose(logits[True], logits[False],
                               rtol=2e-3, atol=2e-3)


def test_quantized_cache_stays_near_unquantized(dense_parts):
    """Sanity: a quantized cache whose static range covers the K/V values
    (fxp8f4: +/-8 at 1/16 resolution — random-init K/V here are ~unit
    scale, outside fxp8f7's +/-1) tracks the bf16-cache logits; the error
    must be quantization-sized, not garbage-sized (catches scale/layout
    bugs)."""
    cfg, rcfg, params = dense_parts
    toks = jnp.asarray(_prompt(1, 8, cfg.vocab_size))[None]
    out = {}
    for spec in (None, QuantSpec(kind="fxp", M=8, F=4)):
        model = _model(cfg, rcfg, kv_spec=spec)
        cache, lg = model.prefill(params, toks, cache=model.init_cache(1, 16))
        cache, lg = model.decode_step(params, cache,
                                      jnp.argmax(lg, -1)[:, None])
        out[spec is None] = np.asarray(lg, np.float32)
    err = np.abs(out[True] - out[False]).mean()
    spread = np.abs(out[True]).mean()
    assert err < 0.5 * spread, (err, spread)


# ---------------------------------------------------------------------------
# Engine: resume bit-identity and kernel/fallback token identity
# ---------------------------------------------------------------------------


_engine = make_engine


@pytest.mark.parametrize("spec", [FXP8, POFX8])
def test_engine_evict_resume_bit_identity_quantized(dense_parts, spec):
    """Quantize-on-write is lossy, so resume must reproduce the CODES the
    evicted request decoded against — static per-channel scales plus
    fake-quant prefill make re-prefill(prompt+prefix) regenerate them
    bit-identically, and the resumed sample stream must match the
    uninterrupted run exactly."""
    cfg, rcfg, params = dense_parts
    model = _model(cfg, rcfg, kv_spec=spec)
    reqs = lambda: [_req(i, cfg.vocab_size, max_new=7, temp=0.7, top_k=8)
                    for i in range(3)]
    ref = {s.req.rid: s.out for s in _engine(model, params).run(reqs())}

    eng = _engine(model, params)
    for r in reqs():
        eng.submit(r)
    eng.admit_ready()
    eng.step()
    victim = eng.active_rids[0]
    eng.evict(victim)
    while eng.pending_rids or eng.active_rids:
        eng.admit_ready()
        eng.step()
    got = {rid: st.out for rid, st in eng._states.items()}
    assert_token_identical(got, ref, label="evict+resume",
                           oracle_label="uninterrupted")
    assert eng._states[victim].n_evictions == 1


def test_engine_greedy_token_identical_kernel_vs_fallback(dense_parts):
    """The acceptance contract: greedy outputs must be token-identical
    between the fused flash-decode kernel and the XLA
    quantize-on-write/dequantize-on-read fallback at the same spec."""
    cfg, rcfg, params = dense_parts
    fallback = _model(cfg, rcfg, kv_spec=FXP8, kv_kernel=False)
    kernel = _model(cfg, rcfg, kv_spec=FXP8, kv_kernel=True)
    differential_engines(
        oracle=lambda: _engine(fallback, params),
        variants={"flash-decode": lambda: _engine(kernel, params)},
        requests=lambda: [_req(i, cfg.vocab_size, max_new=6,
                               arrival=float(i)) for i in range(3)])


def test_engine_preserves_calibrated_kv_scales(dense_parts):
    """Calibrated static scales (written before serving, DESIGN.md §8) must
    survive admission: the batch-1 prefill cache seeds its scale leaves
    from the slot instead of resetting them to init_cache's 1.0, and the
    scatter writes the same calibrated values back."""
    cfg, rcfg, params = dense_parts
    model = _model(cfg, rcfg, kv_spec=FXP8)
    codes = {}
    for cal in (1.0, 2.0):
        eng = _engine(model, params)
        eng.cache = jax.tree_util.tree_map_with_path(
            lambda p, x: jnp.full_like(x, cal)
            if getattr(p[-1], "key", "").endswith("_scale") else x,
            eng.cache)
        eng.run([_req(i, cfg.vocab_size, max_new=5) for i in range(3)])
        kv = eng.cache["kv"]
        np.testing.assert_array_equal(np.asarray(kv["k_scale"]), cal)
        np.testing.assert_array_equal(np.asarray(kv["v_scale"]), cal)
        codes[cal] = np.asarray(kv["k"])
    # the scale actually reaches quantize-on-write: the same K floats
    # normalized by 2x produce different codes
    assert not np.array_equal(codes[1.0], codes[2.0])


def test_engine_chunk_and_slot_invariance_quantized(dense_parts):
    cfg, rcfg, params = dense_parts
    model = _model(cfg, rcfg, kv_spec=FXP8)
    mk = lambda: [_req(i, cfg.vocab_size, max_new=5, temp=0.5, top_k=4,
                       arrival=float(i)) for i in range(3)]
    outs = []
    for slots, chunk in ((2, 1), (2, 4), (3, 2)):
        eng = _engine(model, params, n_slots=slots, chunk=chunk)
        outs.append({s.req.rid: s.out for s in eng.run(mk())})
    assert all(o == outs[0] for o in outs[1:])


@pytest.mark.parametrize("arch", ["moonshot-v1-16b-a3b", "zamba2-1.2b"])
def test_engine_other_families_quantized(tiny, arch):
    """MoE (extra stacking dims) and hybrid (shared attention block) caches
    scatter/serve with code+scale leaves."""
    cfg, model, params = tiny(arch, kv_spec=FXP8)
    done = ServeEngine(model, params, n_slots=2, max_len=24, chunk=3).run(
        [_req(i, cfg.vocab_size, max_new=4, arrival=float(2 * i))
         for i in range(3)])
    for s in done:
        assert len(s.out) == 4
        assert all(0 <= t < cfg.padded_vocab for t in s.out)


# ---------------------------------------------------------------------------
# Property tests (tests/proptest.py harness — the offline stand-in for
# hypothesis): round-trip monotonicity of the cache code path and the
# validate_kv_spec acceptance/rejection partition, beyond the example-based
# cases above.
# ---------------------------------------------------------------------------

_KV_SPECS = [FXP8, QuantSpec(kind="fxp", M=8, F=4), POFX8,
             QuantSpec(kind="pofx", N=6, ES=1),
             QuantSpec(kind="pofx", N=8, ES=2, M=6)]


@given(seed=3, examples=25,
       x=Floats(lo=-4.0, hi=4.0, shape=(64,)),
       spec=Choice(_KV_SPECS),
       scale_exp=Choice([-2, 0, 1, 3]))
def test_kv_roundtrip_monotone_and_bounded(x, spec, scale_exp):
    """kv_dequantize(kv_quantize(x)) is monotone non-decreasing in x —
    both the fxp grid and the posit lattice order codes like the reals —
    saturates instead of wrapping outside the covered range, and is
    deterministic (the bit the resume contract stands on)."""
    scale = float(2.0 ** scale_exp)
    xs = jnp.asarray(np.sort(x), jnp.float32)
    codes = kv_quantize(xs, spec, scale)
    again = kv_quantize(xs, spec, scale)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(again))
    y = np.asarray(kv_dequantize(codes, spec, scale), np.float32)
    assert np.all(np.diff(y) >= 0), (spec, y)
    # saturation: the extreme inputs map to the extreme decoded values
    assert y[0] == y.min() and y[-1] == y.max()
    # within the exactly-covered range the error is grid-sized: one fxp
    # step (2^-F) resp. the coarsest near-1 posit ulp, scaled
    xin = np.asarray(xs)
    inside = np.abs(xin) <= 0.75 * scale
    if inside.any():
        step = scale * (2.0 ** -(spec.F if spec.kind == "fxp" else
                                 max(spec.N - 4, 2)))
        assert np.max(np.abs(y[inside] - xin[inside])) <= step, spec


@given(seed=4, examples=60,
       kind=Choice(["fxp", "posit", "pofx", "bf16", "fp32"]),
       N=Choice([4, 6, 8, 9, 12, 16]),
       M=Choice([4, 6, 8, 9, 12, 16]),
       rounding=Choice(["trunc", "nearest"]))
def test_validate_kv_spec_partition(kind, N, M, rounding):
    """validate_kv_spec accepts exactly: byte-wide fxp/pofx (pofx only with
    trunc rounding); normalizes float kinds to None; rejects the rest with
    the documented reasons."""
    if kind in ("bf16", "fp32"):
        assert validate_kv_spec(QuantSpec(kind=kind)) is None
        return
    spec = QuantSpec(kind=kind, N=N, ES=2, M=M, F=M - 1, rounding=rounding)
    stored = spec.stored_bits
    if kind == "posit":
        with pytest.raises(ValueError, match="fxp or pofx"):
            validate_kv_spec(spec)
    elif stored > 8:
        with pytest.raises(ValueError, match="byte-wide"):
            validate_kv_spec(spec)
    elif kind == "pofx" and rounding != "trunc":
        with pytest.raises(ValueError, match="trunc"):
            validate_kv_spec(spec)
    else:
        assert validate_kv_spec(spec) is spec


def test_engine_kv_quant_with_weight_kernels_smoke(dense_parts):
    """Everything on: pofx weights through the Pallas matmul kernels AND
    the quantized cache through the flash-decode kernel."""
    cfg, rcfg, params = dense_parts
    from repro.nn.models import apply_policy
    model = _model(cfg, rcfg, kv_spec=FXP8, use_kernel=True)
    params = apply_policy(params, "pofx8")
    done = _engine(model, params, max_len=16).run(
        [_req(i, cfg.vocab_size, max_new=3, n=6) for i in range(2)])
    for s in done:
        assert len(s.out) == 3

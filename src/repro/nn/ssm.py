"""State-space sequence mixers: Mamba1 selective scan and Mamba2 SSD.

Both are implemented chunk-parallel so the (B, S, d_inner, state) tensor is
never materialized over the full sequence:

* Mamba1 (falcon-mamba): per-channel diagonal A. Within a chunk of length c
  the recurrence h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t is solved in closed
  form with cumulative sums (log-space prefix products), and chunk-to-chunk
  state is carried by a small jax.lax.scan over S/c steps. This is the
  TPU-native port of the CUDA selective-scan kernel: the FPGA/GPU trick
  (fused recurrent kernel) becomes "batched matmul-sized chunks + tiny carry
  scan", which keeps the MXU/VPU busy instead of emulating a serial loop.

* Mamba2 (zamba2): scalar-per-head A (SSD). The chunked SSD algorithm of the
  Mamba2 paper maps 1:1 onto MXU matmuls: intra-chunk (C B^T ⊙ L) X plus
  inter-chunk state passing. chunk = cfg.ssm_chunk.

Decode is the exact single-step recurrence against a carried (B, ...) state
(the SSM analogue of a KV cache; size is sequence-independent, which is why
the long_500k cell is assigned to these families).

Parameter quantization (the paper's technique) applies to the in/out/x
projections; A_log, dt_bias, D and norms stay fp32 — the recurrence is
error-accumulating (documented inapplicability, DESIGN.md §5).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import Param, dense_init, matmul_param, param_value, rmsnorm

# ---------------------------------------------------------------------------
# Mamba1 (falcon-mamba-7b)
# ---------------------------------------------------------------------------


def mamba1_init(key, cfg, dtype=jnp.float32) -> dict:
    d, di, ds, dr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    ks = jax.random.split(key, 6)
    # A initialized to -[1..ds] per channel (S4D-real), stored as log.
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, di)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dr + 2 * ds, dtype=dtype),
        "dt_proj": dense_init(ks[3], dr, di, scale=dr**-0.5, dtype=dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.exp(
            jax.random.uniform(ks[4], (di,), minval=jnp.log(1e-3), maxval=jnp.log(1e-1))
        ))).astype(jnp.float32),
        "A_log": jnp.log(a),                       # fp32 always
        "D": jnp.ones((di,), jnp.float32),         # fp32 always
        "out_proj": dense_init(ks[5], di, d, dtype=dtype),
    }


def mamba1_logical() -> dict:
    return {
        "in_proj": ("p_embed", "d_inner"),
        "conv_w": ("conv", "d_inner"),
        "conv_b": ("d_inner",),
        "x_proj": ("d_inner_r", "p_unsharded"),
        "dt_proj": ("p_unsharded", "d_inner"),
        "dt_bias": ("d_inner",),
        "A_log": ("d_inner", "state"),
        "D": ("d_inner",),
        "out_proj": ("d_inner_r", "p_embed"),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv over seq. x: (B, S, d); w: (K, d).

    state: (B, K-1, d) trailing inputs from the previous segment (decode /
    chunked prefill). Returns (y, new_state).
    """
    K = w.shape[0]
    B, S, d = x.shape
    if state is None:
        state = jnp.zeros((B, K - 1, d), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = jnp.zeros((B, S, d), jnp.float32)
    for i in range(K):  # K is 4: unrolled taps, no conv primitive needed
        y = y + xp[:, i:i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    # keep the cache dtype stable across steps: a decode cache initialized
    # f32 must not silently become bf16 after the first step (the engine
    # scans decode_step, and a lax.scan carry rejects the dtype flip)
    new_state = xp[:, S:].astype(state.dtype)
    return (y + b.astype(jnp.float32)).astype(x.dtype), new_state


def _chunk_scan_diag(dA: jax.Array, dBx: jax.Array, h0: jax.Array):
    """Solve h_t = dA_t * h_{t-1} + dBx_t within a chunk, diagonal dA.

    dA, dBx: (B, c, ...) with matching trailing dims; h0: (B, ...).
    Returns (h_all (B, c, ...), h_last). Associative scan over the linear
    recurrence: composing (A1,b1) then (A2,b2) gives (A2*A1, A2*b1 + b2).
    All products stay in (0, 1] (dA = exp(dt*A), A < 0), so this is
    overflow-free where the naive 1/prefix-product rescale was not.
    """
    def comb(left, right):
        a1, b1 = left
        a2, b2 = right
        return a2 * a1, a2 * b1 + b2

    A, Bv = jax.lax.associative_scan(comb, (dA, dBx), axis=1)
    h = A * h0[:, None] + Bv
    return h, h[:, -1]


def mamba1_mix(p: dict, xz: jax.Array, cfg, *, conv_state=None, ssm_state=None,
               chunk: Optional[int] = None):
    """Core mamba1 mixer after in_proj. xz: (B, S, 2*di).

    Returns (y (B, S, di-projected d), new_conv_state, new_ssm_state).
    """
    di, ds, dr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    x, z = jnp.split(xz, 2, axis=-1)
    x, new_conv = _causal_conv(x, param_value(p["conv_w"], jnp.float32),
                               param_value(p["conv_b"], jnp.float32), conv_state)
    x = jax.nn.silu(x)
    # input-dependent dt, B, C
    dbc = matmul_param(x, p["x_proj"])
    dt, Bm, Cm = jnp.split(dbc, [dr, dr + ds], axis=-1)
    dt = matmul_param(dt, p["dt_proj"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(jnp.float32))  # (B,S,di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (di,ds)
    B_, S_, _ = x.shape
    c = chunk or min(cfg.ssm_chunk, S_)
    while S_ % c:
        c -= 1
    xf = x.astype(jnp.float32)
    dA = jnp.exp(dt[..., None] * A)                               # (B,S,di,ds)
    dBx = (dt * xf)[..., None] * Bm.astype(jnp.float32)[..., None, :]
    if ssm_state is None:
        ssm_state = jnp.zeros((B_, di, ds), jnp.float32)

    def step(h, blk):
        dA_c, dBx_c, C_c = blk
        h_all, h_last = _chunk_scan_diag(dA_c, dBx_c, h)
        y_c = jnp.einsum("bcds,bcs->bcd", h_all, C_c)
        return h_last, y_c

    n = S_ // c
    blocks = (
        dA.reshape(B_, n, c, di, ds).swapaxes(0, 1),
        dBx.reshape(B_, n, c, di, ds).swapaxes(0, 1),
        Cm.astype(jnp.float32).reshape(B_, n, c, ds).swapaxes(0, 1),
    )
    h_last, ys = jax.lax.scan(step, ssm_state, blocks)
    y = ys.swapaxes(0, 1).reshape(B_, S_, di)
    y = y + xf * p["D"].astype(jnp.float32)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    return y.astype(xz.dtype), new_conv, h_last


def mamba1_forward(p: dict, x: jax.Array, cfg, ctx, *, cache: Optional[dict] = None,
                   use_kernel: bool = False):
    """Full mamba1 block. x: (B, S, d). cache: {"conv": ..., "ssm": ...}."""
    xz = matmul_param(x, p["in_proj"], use_kernel=use_kernel)
    xz = ctx.constrain(xz, "batch", "seq_attn", "d_inner2")
    conv_s = cache["conv"] if cache else None
    ssm_s = cache["ssm"] if cache else None
    y, new_conv, new_ssm = mamba1_mix(p, xz, cfg, conv_state=conv_s, ssm_state=ssm_s)
    out = matmul_param(y, p["out_proj"], use_kernel=use_kernel)
    new_cache = {"conv": new_conv, "ssm": new_ssm} if cache is not None else None
    return out, new_cache


def mamba1_init_cache(cfg, batch: int, dtype=jnp.float32) -> dict:
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner), dtype),
        "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Mamba2 / SSD (zamba2)
# ---------------------------------------------------------------------------


def mamba2_init(key, cfg, dtype=jnp.float32) -> dict:
    d, di, ds = cfg.d_model, cfg.d_inner, cfg.ssm_state
    nh = di // cfg.ssm_head_dim
    ks = jax.random.split(key, 4)
    # in_proj packs [z (di), x (di), B (ds), C (ds), dt (nh)]
    return {
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * ds + nh, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, di + 2 * ds)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di + 2 * ds,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], di, d, dtype=dtype),
    }


def mamba2_logical() -> dict:
    return {
        "in_proj": ("p_embed", "d_inner2"),
        "conv_w": ("conv", "d_inner2"),
        "conv_b": ("d_inner2",),
        "A_log": ("heads_r",),
        "dt_bias": ("heads_r",),
        "D": ("heads_r",),
        "norm_w": ("d_inner",),
        "out_proj": ("d_inner_r", "p_embed"),
    }


def _ssd_chunk(x, dt, A, Bm, Cm, h0):
    """One SSD chunk. x: (B,c,nh,dh); dt: (B,c,nh); A: (nh,) negative;
    Bm/Cm: (B,c,ds); h0: (B,nh,dh,ds). Returns (y, h_last).

    Mamba2 alg: with a_t = exp(dt_t A) per head,
      intra: y_t  = C_t · sum_{i<=t} (prod_{i<j<=t} a_j) dt_i B_i x_i
      inter: y_t += C_t · (prod_{i<=t} a_i) h0
    realized as matmuls with the L (decay) mask — all MXU work.
    """
    Bsz, c, nh, dh = x.shape
    la = dt * A  # (B,c,nh) log decay, <= 0
    cum = jnp.cumsum(la, axis=1)                       # log prod_{i<=t}
    # L[t, i] = exp(cum_t - cum_i) for i <= t else 0  (decay from i+1..t).
    # Mask BEFORE exp: the i > t entries are positive and overflow to inf,
    # and inf * 0 in the select backward poisons the gradient.
    Lm = cum[:, :, None, :] - cum[:, None, :, :]        # (B,t,i,nh)
    tri = jnp.tril(jnp.ones((c, c), bool))
    Lm = jnp.exp(jnp.where(tri[None, :, :, None], Lm, -1e30))
    CB = jnp.einsum("bts,bis->bti", Cm, Bm)             # (B,t,i)
    W = CB[..., None] * Lm                              # (B,t,i,nh)
    dx = dt[..., None] * x                              # (B,c,nh,dh)
    y = jnp.einsum("btih,bihd->bthd", W, dx)            # intra-chunk
    # inter-chunk: contribution of the incoming state h0, decayed to step t
    decay0 = jnp.exp(cum)                               # (B,c,nh)
    y = y + jnp.einsum("bts,bhds,bth->bthd", Cm, h0, decay0)
    # state update: h_last = exp(cum_last) h0 + sum_i exp(cum_last - cum_i) dt_i B_i x_i
    w_last = jnp.exp(cum[:, -1:, :] - cum)              # (B,c,nh)
    h_last = (jnp.exp(cum[:, -1])[:, :, None, None] * h0
              + jnp.einsum("bih,bihd,bis->bhds", w_last, dx, Bm))
    return y, h_last


def mamba2_mix(p: dict, zxbcdt: jax.Array, cfg, *, conv_state=None, ssm_state=None,
               chunk: Optional[int] = None):
    """Core mamba2 mixer after in_proj. zxbcdt: (B, S, 2di+2ds+nh)."""
    di, ds, dh = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    nh = di // dh
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * ds], axis=-1)
    xBC, new_conv = _causal_conv(xBC, param_value(p["conv_w"], jnp.float32),
                                 param_value(p["conv_b"], jnp.float32), conv_state)
    xBC = jax.nn.silu(xBC)
    x, Bm, Cm = jnp.split(xBC, [di, di + ds], axis=-1)
    Bsz, S, _ = x.shape
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))        # (nh,)
    xh = x.astype(jnp.float32).reshape(Bsz, S, nh, dh)
    c = chunk or min(cfg.ssm_chunk, S)
    while S % c:
        c -= 1
    n = S // c
    if ssm_state is None:
        ssm_state = jnp.zeros((Bsz, nh, dh, ds), jnp.float32)

    def step(h, blk):
        x_c, dt_c, B_c, C_c = blk
        y_c, h_last = _ssd_chunk(x_c, dt_c, A, B_c, C_c, h)
        return h_last, y_c

    blocks = (
        xh.reshape(Bsz, n, c, nh, dh).swapaxes(0, 1),
        dt.reshape(Bsz, n, c, nh).swapaxes(0, 1),
        Bm.astype(jnp.float32).reshape(Bsz, n, c, ds).swapaxes(0, 1),
        Cm.astype(jnp.float32).reshape(Bsz, n, c, ds).swapaxes(0, 1),
    )
    h_last, ys = jax.lax.scan(step, ssm_state, blocks)
    y = ys.swapaxes(0, 1).reshape(Bsz, S, nh, dh)
    y = y + xh * p["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(Bsz, S, di)
    # gated RMSNorm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(y.astype(zxbcdt.dtype), p["norm_w"], cfg.norm_eps)
    return y, new_conv, h_last


def mamba2_forward(p: dict, x: jax.Array, cfg, ctx, *, cache: Optional[dict] = None,
                   use_kernel: bool = False):
    zxbcdt = matmul_param(x, p["in_proj"], use_kernel=use_kernel)
    zxbcdt = ctx.constrain(zxbcdt, "batch", "seq_attn", "d_inner2")
    conv_s = cache["conv"] if cache else None
    ssm_s = cache["ssm"] if cache else None
    y, new_conv, new_ssm = mamba2_mix(p, zxbcdt, cfg, conv_state=conv_s, ssm_state=ssm_s)
    out = matmul_param(y, p["out_proj"], use_kernel=use_kernel)
    new_cache = {"conv": new_conv, "ssm": new_ssm} if cache is not None else None
    return out, new_cache


def mamba2_init_cache(cfg, batch: int, dtype=jnp.float32) -> dict:
    di, ds, dh = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    nh = di // dh
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * ds), dtype),
        "ssm": jnp.zeros((batch, nh, dh, ds), jnp.float32),
    }


# Sequential references (the correctness oracles for tests) -----------------


def mamba1_mix_ref(p: dict, xz: jax.Array, cfg):
    """Naive per-timestep recurrence, float64-free but step-exact."""
    return mamba1_mix(p, xz, cfg, chunk=1)


def mamba2_mix_ref(p: dict, zxbcdt: jax.Array, cfg):
    return mamba2_mix(p, zxbcdt, cfg, chunk=1)

import os
import sys

# Tests run on the single real CPU device; the 512-device dry-run sets its
# own XLA_FLAGS in a separate process (see launch/dryrun.py), and the
# tensor-parallel suite (test_tp_engine.py) runs under the CI multi-device
# job's XLA_FLAGS=--xla_force_host_platform_device_count=4.
sys.path.insert(0, os.path.dirname(__file__))

import pytest


@pytest.fixture(scope="session")
def tiny():
    """Session-cached tiny-model factory shared by every engine/model suite.

    ``tiny(arch, **build_kw) -> (cfg, model, params)`` builds the smoke
    reduction of an assigned arch; parameters are initialized ONCE per
    (arch, overrides) and shared across tests — engines donate their cache,
    never their params, and quantization (apply_policy) copies, so sharing
    is safe and saves the repeated per-module init the old per-file
    fixtures paid.

    * ``drop_free=True``: MoE capacity_factor=100 (forward/decode/microbatch
      comparisons must not differ by which tokens an expert dropped).
    * ``cfg_overrides``: dataclasses.replace overrides on the smoke config
      (e.g. the TP suite's MHA dense variant, ``n_kv_heads=4``).
    * ``rcfg``: RunConfig override (default ``RunConfig(remat="none")``).
    * remaining ``build_kw`` goes to ``build_model`` (mesh/use_kernel/
      kv_spec/kv_kernel) — models are cheap facades, built per call.
    """
    import dataclasses

    import jax

    from repro.configs import ARCHS, RunConfig, smoke
    from repro.nn.models import build_model

    cfgs, params_cache = {}, {}

    def get(arch, *, drop_free=False, cfg_overrides=None, rcfg=None,
            **build_kw):
        over = tuple(sorted((cfg_overrides or {}).items()))
        ckey = (arch, drop_free, over)
        if ckey not in cfgs:
            cfg = smoke(ARCHS[arch])
            if drop_free and cfg.family == "moe":
                cfg = dataclasses.replace(cfg, capacity_factor=100.0)
            if cfg_overrides:
                cfg = dataclasses.replace(cfg, **cfg_overrides)
            cfgs[ckey] = cfg
        cfg = cfgs[ckey]
        if ckey not in params_cache:
            base = build_model(cfg, RunConfig(remat="none"))
            params_cache[ckey] = base.init(jax.random.PRNGKey(0))
        model = build_model(cfg, rcfg or RunConfig(remat="none"), **build_kw)
        return cfg, model, params_cache[ckey]

    return get

"""Shared benchmark utilities: paper-like weight distributions, decode-cost
probes, CSV writing."""
from __future__ import annotations

import csv
import os
import time
from typing import Dict, List

import jax
import numpy as np

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "bench")


def vgg_like_weights(n: int = 1 << 16, seed: int = 0) -> np.ndarray:
    """Pre-trained-conv-like weight sample (paper Fig. 1: VGG16 Conv2_1 —
    near-normal, heavy mass near 0, range about [-0.3, 0.3])."""
    rng = np.random.default_rng(seed)
    w = rng.normal(0.0, 0.05, size=n)
    return np.clip(w, -0.3, 0.3)


def avg_abs_rel_error(w: np.ndarray, wq: np.ndarray, eps: float = 1e-8) -> float:
    return float(np.mean(np.abs(wq - w) / np.maximum(np.abs(w), eps)))


def wall_time(fn, *args, reps: int = 3) -> float:
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def jaxpr_ops(fn, *args) -> int:
    """Static op count of the jaxpr — the CPD/LUT-count analogue we can
    measure without hardware (deeper decode == more primitive ops)."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    return sum(1 for _ in jaxpr.jaxpr.eqns)


def decode_fn(spec):
    """Storage-code -> value decode lambda for one QuantSpec kind (the
    bit-level datapath the CPD/op-count probes measure); None for float
    passthrough kinds."""
    from repro.core import fxp as fxp_mod
    from repro.core.pofx import pofx_normalized
    from repro.core.posit import posit_decode

    if spec.kind == "fxp":
        return lambda c: fxp_mod.fxp_dequantize(c, spec.F)
    if spec.kind == "posit":
        return lambda c: posit_decode(c, spec.N, spec.ES)
    if spec.kind == "pofx":
        return lambda c: pofx_normalized(c, spec.N, spec.ES, spec.M)[0]
    return None


def write_csv(name: str, rows: List[Dict]) -> str:
    # REPRO_BENCH_OUT redirects artifacts (tests/test_bench_smoke.py writes
    # to a tmp dir so smoke rows never clobber the committed CSVs)
    out_dir = os.environ.get("REPRO_BENCH_OUT") or OUT_DIR
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{name}.csv")
    if not rows:
        return path
    # union of keys in first-seen order: mixes may report extra columns
    # (e.g. the longctx KV-traffic fields) without breaking the writer
    keys = list(dict.fromkeys(k for r in rows for k in r))
    with open(path, "w", newline="") as f:
        wr = csv.DictWriter(f, fieldnames=keys, restval="")
        wr.writeheader()
        for r in rows:
            wr.writerow(r)
    return path

"""Fig. 1 / Fig. 16: quantization-induced weight error by scheme.

Paper's headline numbers on VGG16 Conv2_1: FxP8 avg-abs-relative error
0.295 vs Posit(8,2) 0.052. We reproduce on the same distribution family and
sweep the full (N, ES) grid; §Claims checks FxP8 error >> Posit(8,2).
"""
from __future__ import annotations

import numpy as np

from repro.core import fxp
from repro.core.normalized_posit import norm_decode_np, norm_encode_np
from repro.core.posit import posit_decode_np, posit_encode_np

from .common import avg_abs_rel_error, vgg_like_weights, write_csv


def run(smoke: bool = False):
    # smoke (benchmarks.run --smoke / tests/test_bench_smoke.py): same
    # sweep on a smaller weight sample — exercises every code path cheaply
    w = vgg_like_weights(1 << 12 if smoke else 1 << 16)
    rows = []
    for M in (7, 8, 16):
        wq = fxp.fxp_dequantize_np(fxp.fxp_quantize_np(w, M, M - 1), M - 1)
        rows.append({"scheme": f"fxp{M}", "avg_rel": avg_abs_rel_error(w, wq),
                     "max_abs": float(np.max(np.abs(wq - w))),
                     "bits": M})
    for N in (5, 6, 7, 8):
        for ES in (0, 1, 2, 3):
            wq = posit_decode_np(posit_encode_np(w, N, ES), N, ES)
            rows.append({"scheme": f"posit({N},{ES})",
                         "avg_rel": avg_abs_rel_error(w, wq),
                         "max_abs": float(np.max(np.abs(wq - w))),
                         "bits": N})
            wq = norm_decode_np(norm_encode_np(w, N, ES), N, ES)
            rows.append({"scheme": f"normposit({N - 1},{ES})",
                         "avg_rel": avg_abs_rel_error(w, wq),
                         "max_abs": float(np.max(np.abs(wq - w))),
                         "bits": N - 1})
    write_csv("fig1_quant_error", rows)
    by = {r["scheme"]: r["avg_rel"] for r in rows}
    claim = by["fxp8"] / by["posit(8,2)"]
    return rows, {
        "fxp8_avg_rel": by["fxp8"],
        "posit82_avg_rel": by["posit(8,2)"],
        "ratio_fxp8_over_posit82": claim,
        "claim_posit_much_better": claim > 3.0,   # paper: 0.295/0.052 = 5.7x
    }

"""Property-based tests (hypothesis) for the numeric-format core.

System invariants the paper's correctness rests on:
  * posit decode/encode are exact inverses on the code lattice,
  * encode is round-to-nearest (no value maps to a farther code),
  * normalized posit compress/expand is a bijection on the sub-unit lattice,
  * PoFx(Algorithm 1) == arithmetic reference decode for every (N, ES, M),
  * FxP quantization error <= half an ulp,
  * monotonicity: posit codes order like the reals they represent,
  * pack/unpack bit-streams are lossless,
  * posit-compressed mean transport error is bounded by the lattice step.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import fxp
from repro.core.normalized_posit import (norm_compress, norm_decode_np,
                                         norm_encode_np, norm_expand,
                                         norm_max, pack_bits, unpack_bits)
from repro.core.pofx import pofx_convert_np, pofx_normalized_np
from repro.core.posit import (NAR, posit_decode_np, posit_encode_np,
                              posit_value_table)

config = st.tuples(st.integers(4, 10), st.integers(0, 3))


@given(config)
@settings(max_examples=40, deadline=None)
def test_posit_roundtrip_is_identity(cfg):
    N, ES = cfg
    codes = np.arange(1 << N)
    vals = posit_decode_np(codes, N, ES)
    finite = codes[~np.isnan(vals)]
    back = posit_encode_np(vals[~np.isnan(vals)], N, ES)
    np.testing.assert_array_equal(back, finite)


@given(config, st.lists(st.floats(-300, 300, allow_nan=False), min_size=1,
                        max_size=64))
@settings(max_examples=40, deadline=None)
def test_posit_encode_is_nearest(cfg, xs):
    N, ES = cfg
    x = np.asarray(xs)
    codes = posit_encode_np(x, N, ES)
    got = posit_decode_np(codes, N, ES)
    table = posit_value_table(N, ES)
    full = np.concatenate([-table[::-1], table])
    for xi, gi in zip(x, got):
        best = full[np.argmin(np.abs(full - xi))]
        assert abs(gi - xi) <= abs(best - xi) + 1e-12 * max(abs(xi), 1)


@given(config)
@settings(max_examples=40, deadline=None)
def test_normalized_bijection(cfg):
    N, ES = cfg
    codes = np.arange(1 << (N - 1))
    assert np.array_equal(norm_compress(norm_expand(codes, N), N), codes)
    vals = norm_decode_np(codes, N, ES)
    assert np.all(np.abs(vals) <= 1.0)


@given(config, st.integers(6, 16))
@settings(max_examples=40, deadline=None)
def test_pofx_matches_arithmetic_decode(cfg, M):
    """Algorithm 1's bit-level output == round(value * 2^F) truncated."""
    N, ES = cfg
    codes = np.arange(1 << (N - 1))
    fxp_codes, of = pofx_normalized_np(codes, N, ES, M)
    vals = norm_decode_np(codes, N, ES)
    F = M - 1
    expect = np.trunc(vals * (1 << F))  # stage D truncates toward zero
    expect = np.clip(expect, -(2 ** (M - 1) - 1), 2 ** (M - 1) - 1)
    np.testing.assert_array_equal(fxp_codes, expect.astype(np.int64))


@given(st.integers(4, 12), st.integers(0, 3),
       st.lists(st.floats(-0.999, 0.999), min_size=1, max_size=32))
@settings(max_examples=40, deadline=None)
def test_norm_encode_error_bounded_by_lattice_gap(N, ES, xs):
    x = np.asarray(xs)
    codes = norm_encode_np(x, N, ES)
    back = norm_decode_np(codes, N, ES)
    # error bounded by the largest gap between adjacent normalized codes
    grid = norm_decode_np(np.arange(1 << (N - 1)), N, ES)
    grid = np.sort(grid)
    gap = np.max(np.diff(grid))
    assert np.max(np.abs(back - np.clip(x, -1, norm_max(N, ES)))) <= gap


@given(st.integers(4, 16), st.integers(2, 200))
@settings(max_examples=40, deadline=None)
def test_pack_unpack_lossless(k, n):
    rng = np.random.default_rng(n)
    codes = rng.integers(0, 1 << k, size=n).astype(np.int64)
    packed = pack_bits(codes, k)
    assert packed.nbytes <= (n * k + 7) // 8 + 1
    out = unpack_bits(packed, k, n)
    np.testing.assert_array_equal(out, codes)


@given(st.integers(3, 15),
       st.lists(st.floats(-100, 100), min_size=1, max_size=32))
@settings(max_examples=40, deadline=None)
def test_fxp_half_ulp(M, xs):
    F = M - 1
    x = np.asarray(xs) / 128.0
    codes = fxp.fxp_quantize_np(x, M, F)
    back = fxp.fxp_dequantize_np(codes, F)
    ulp = 2.0 ** -F
    in_range = np.abs(x) < (2 ** (M - 1) - 1) * ulp
    assert np.all(np.abs(back[in_range] - x[in_range]) <= ulp / 2 + 1e-12)


@given(config)
@settings(max_examples=30, deadline=None)
def test_posit_monotonic_in_signed_code_order(cfg):
    N, ES = cfg
    codes = np.arange(1 << N)
    vals = posit_decode_np(codes, N, ES)
    signed = np.where(codes >= (1 << (N - 1)), codes - (1 << N), codes)
    order = np.argsort(signed)
    v = vals[order]
    v = v[~np.isnan(v)]
    assert np.all(np.diff(v) > 0)


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_compressed_mean_bounded(seed):
    """Transport error of the posit8 gradient codec stays within the
    normalized-lattice gap times the pow2 scale."""
    rng = np.random.default_rng(seed)
    g = rng.normal(size=64).astype(np.float64) * 10.0 ** rng.integers(-6, 2)
    amax = np.max(np.abs(g)) or 1.0
    scale = 2.0 ** np.ceil(np.log2(amax))
    codes = norm_encode_np(g / scale, 8, 2)
    back = norm_decode_np(codes, 8, 2) * scale
    grid = np.sort(norm_decode_np(np.arange(1 << 7), 8, 2))
    gap = np.max(np.diff(grid)) * scale
    assert np.max(np.abs(back - g)) <= gap + 1e-12

"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel in this package must match its oracle bit-exactly (integer
decode paths) or to float tolerance (accumulating matmuls) across the shape/
dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.pofx import pofx_normalized

__all__ = ["pofx_decode_ref", "pofx_matmul_ref", "fxp_matmul_ref",
           "decode_norm_to_fxp", "kv_flash_decode_ref",
           "kv_flash_paged_decode_ref", "gather_pages"]


def decode_norm_to_fxp(codes, N: int, ES: int, M: int):
    """Normalized posit codes -> FxP(M, M-1) two's-complement int32.

    This is the elementwise function both the oracle and the kernels share:
    bit-level Algorithm 1 (stages A-E), jnp ops only, Pallas-safe.
    """
    out, _ = pofx_normalized(codes, N, ES, M)
    return out


def pofx_decode_ref(codes, N: int, ES: int, M: int = 8) -> jax.Array:
    """Oracle for the decode kernel: uint8 codes -> int8 FxP codes."""
    return decode_norm_to_fxp(codes.astype(jnp.int32), N, ES, M).astype(jnp.int8)


def pofx_matmul_ref(x, codes, scale, N: int, ES: int, M: int = 8) -> jax.Array:
    """Oracle for the fused Move&Store kernel.

    x: (m, k) float; codes: (k, n) normalized posit; scale: (1, n) or (n,)
    per-output-channel normalizer. Result fp32: x @ (decode(codes)/2^(M-1)) * scale.
    """
    fxp = decode_norm_to_fxp(codes.astype(jnp.int32), N, ES, M)
    w = fxp.astype(jnp.float32) * (1.0 / (1 << (M - 1)))
    y = jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)
    return y * jnp.reshape(scale, (1, -1)).astype(jnp.float32)


def kv_flash_decode_ref(q, k_codes, k_scale, v_codes, v_scale, pos,
                        spec) -> jax.Array:
    """Oracle for the fused KV flash-decode kernel: the XLA fallback path.

    Dequantize the whole cache (codes -> FxP -> value * scale), then plain
    masked softmax attention — mathematically identical to the kernel's
    online softmax, computed out-of-place in f32.

    q: (B, G, R, Dh); codes: (B, G, S, Dh); scales: (B, G, 1, Dh);
    pos: scalar or (B,) valid lengths.
    """
    from repro.core.quantizers import kv_dequantize

    S = k_codes.shape[2]
    k = kv_dequantize(k_codes, spec, k_scale, jnp.float32)
    v = kv_dequantize(v_codes, spec, v_scale, jnp.float32)
    s = jnp.einsum("bgrd,bgsd->bgrs", q.astype(jnp.float32), k,
                   preferred_element_type=jnp.float32) * q.shape[-1] ** -0.5
    valid = jnp.arange(S)[None, :] < jnp.reshape(pos, (-1, 1))
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgrs,bgsd->bgrd", p, v,
                      preferred_element_type=jnp.float32)


def gather_pages(pool, tables) -> jax.Array:
    """Materialize per-slot contiguous caches from a page pool.

    pool: (n_pages, G, ps, Dh); tables: (B, max_pages) physical page ids
    (garbage-page entries gather junk that per-slot ``pos`` masks).
    Returns (B, G, max_pages * ps, Dh) — the heads-major layout
    ``decode_attention`` expects. This is the XLA fallback's read path and
    the indirection half of the paged kernel's oracle.
    """
    B, max_pages = tables.shape
    _, G, ps, Dh = pool.shape
    gathered = pool[tables]                       # (B, max_pages, G, ps, Dh)
    return jnp.transpose(gathered, (0, 2, 1, 3, 4)).reshape(
        B, G, max_pages * ps, Dh)


def kv_flash_paged_decode_ref(q, k_pool, k_scale, v_pool, v_scale, tables,
                              pos, spec) -> jax.Array:
    """Oracle for the paged KV flash-decode kernel.

    Gather every slot's pages into a contiguous cache, then run the dense
    oracle. Pool scales are global per layer ((G, 1, Dh) — pages are
    shareable across slots only because they quantize under one grid), so
    they broadcast over the gathered batch axis.

    q: (B, G, R, Dh); pools: (n_pages, G, ps, Dh); scales: (G, 1, Dh);
    tables: (B, max_pages) int32; pos: scalar or (B,) valid lengths.
    """
    k = gather_pages(k_pool, tables)
    v = gather_pages(v_pool, tables)
    return kv_flash_decode_ref(q, k, k_scale[None], v, v_scale[None], pos,
                               spec)


def fxp_matmul_ref(a, b) -> jax.Array:
    """Oracle for the FxP MAC kernel: int8 x int8 -> int32 accumulate.

    The int32 accumulator is the TPU analogue of the paper's 3M-bit adder
    (M=8 -> 24 bits of headroom needed; int32 provides 32).
    """
    return jnp.dot(a.astype(jnp.int32), b.astype(jnp.int32),
                   preferred_element_type=jnp.int32)

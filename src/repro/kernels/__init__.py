"""repro.kernels — Pallas TPU kernels for the PoFx hot path.

pofx_decode: VPU bit-parallel Algorithm-1 decode (posit codes -> FxP int8)
pofx_matmul: fused Move&Store kernel (decode in VMEM -> MXU matmul)
fxp_matmul:  int8 x int8 -> int32 MAC (the paper's FxP baseline)
ref:         pure-jnp oracles; every kernel is allclose-tested against them.
"""
from .ops import fxp_matmul, pofx_decode, pofx_matmul, quant_matmul  # noqa: F401

"""Benchmark harness entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig1,table5,...] \
        [--quant pofx5es1,fxp6]

Each bench returns (rows, claims). Rows land in experiments/bench/*.csv;
the claims dict is printed as ``bench,claim,value`` lines — EXPERIMENTS.md
§Claims is generated from this output. ``--quant`` (the shared policy/spec
grammar, see repro.core.policy) appends extra comma-separated spec strings
to every format-sweeping bench that accepts them.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback

BENCHES = [
    ("fig1_quant_error", "benchmarks.bench_fig1_quant_error"),
    ("fig2_tradeoff", "benchmarks.bench_fig2_tradeoff"),
    ("table2_normposit", "benchmarks.bench_table2_normposit"),
    ("fig10_pofx", "benchmarks.bench_fig10_pofx"),
    ("table3_pareto", "benchmarks.bench_table3_pareto"),
    ("table5_accuracy", "benchmarks.bench_table5_accuracy"),
    ("table6_joint", "benchmarks.bench_table6_joint"),
    ("fig20_accel", "benchmarks.bench_fig20_accel"),
    ("kernels", "benchmarks.bench_kernels"),
    ("roofline", "benchmarks.bench_roofline"),
    ("serve_engine", "benchmarks.bench_serve_engine"),
]


def main(argv=None) -> int:
    from repro.core.policy import add_policy_arg, parse_spec

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated bench name substrings")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny inputs for every bench that supports them — "
                         "a bit-rot check (tests/test_bench_smoke.py runs "
                         "this per bench in CI), not a measurement")
    add_policy_arg(ap, default="",
                   extra_help="extra spec strings appended to the "
                              "format-sweeping benches")
    args = ap.parse_args(argv)
    only = [s for s in args.only.split(",") if s]
    extra_specs = tuple(s for s in args.quant.split(",") if s)
    for s in extra_specs:
        if parse_spec(s) is None:  # fail fast on typos / the keep sentinel
            raise SystemExit(f"--quant: {s!r} is not a quantized format")
    failures = []
    for name, module in BENCHES:
        if only and not any(s in name for s in only):
            continue
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["run"])
            kwargs = {}
            sig = inspect.signature(mod.run).parameters
            if "extra_specs" in sig:
                kwargs["extra_specs"] = extra_specs
            if args.smoke and "smoke" in sig:
                kwargs["smoke"] = True
            rows, claims = mod.run(**kwargs)
            dt = time.time() - t0
            print(f"=== {name}: {len(rows)} rows in {dt:.1f}s")
            for k, v in claims.items():
                print(f"{name},{k},{v}")
        except Exception:
            failures.append(name)
            print(f"=== {name}: FAILED")
            traceback.print_exc()
    if failures:
        print(f"FAILED benches: {failures}")
        return 1
    print("all benches ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())

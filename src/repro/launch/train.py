"""Training step builder + CLI driver.

``make_train_step`` assembles the full production step:

  microbatch gradient accumulation (lax.scan, f32 accumulators)
  -> optional posit8-compressed cross-pod gradient mean
     (shard_map manual over "pod", GSPMD auto over data/model)
  -> global-norm clip + AdamW (optionally posit8-compressed moments)

The CLI driver runs a real training loop on whatever devices exist:
data pipeline -> jit train step (donated state) -> async checkpoints
(auto-resume) -> straggler monitor. ``--smoke`` shrinks the arch so the
loop runs on this CPU container; the same entry point drives a pod.

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, RunConfig, smoke as smoke_cfg
from repro.nn.models import LM, build_model
from repro.nn.sharding import shard_map_compat
from repro.optim import OptConfig, apply_updates, init_opt_state
from repro.runtime.compression import compressed_grad_transform

__all__ = ["make_train_state", "make_train_step", "opt_config_from_run"]


def opt_config_from_run(rcfg: RunConfig) -> OptConfig:
    return OptConfig(
        learning_rate=rcfg.learning_rate,
        warmup_steps=rcfg.warmup_steps,
        total_steps=rcfg.total_steps,
        weight_decay=rcfg.weight_decay,
        grad_clip=rcfg.grad_clip,
        quant="posit8" if rcfg.opt_state_quant == "posit8" else "none",
    )


def make_train_state(model: LM, key) -> Dict[str, Any]:
    params = model.init(key)
    state = {"params": params,
             "opt": init_opt_state(params, opt_config_from_run(model.rcfg).quant)}
    if model.rcfg.grad_compression == "posit8_ef":
        state["ef"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def abstract_train_state(model: LM) -> Dict[str, Any]:
    return jax.eval_shape(lambda: make_train_state(model, jax.random.PRNGKey(0)))


def state_shardings(model: LM, abstract: Optional[Dict[str, Any]] = None):
    """NamedSharding tree for the train state: opt moments like params.

    posit8 moments are QuantizedTensor leaves: codes shard like the param,
    the (tiny) per-tensor scale is replicated.
    """
    from jax.sharding import NamedSharding
    from repro.core.quantizers import QuantizedTensor

    abstract = abstract or abstract_train_state(model)
    p_shard = model.param_shardings(abstract["params"])
    mesh = model.ctx.mesh
    repl = NamedSharding(mesh, P()) if mesh is not None else None

    def like_params(moments):
        leaves_s, treedef = jax.tree.flatten(p_shard,
                                             is_leaf=lambda x: x is None)
        m_objs = treedef.flatten_up_to(moments)
        out = []
        for s, m in zip(leaves_s, m_objs):
            if isinstance(m, QuantizedTensor):
                out.append(QuantizedTensor(s, repl, m.spec))
            else:
                out.append(s)
        return treedef.unflatten(out)

    out = {"params": p_shard,
           "opt": {"m": like_params(abstract["opt"]["m"]),
                   "v": like_params(abstract["opt"]["v"]),
                   "count": repl}}
    if "ef" in abstract:
        out["ef"] = p_shard
    return out


def batch_shardings(model: LM, batch_abstract):
    ctx = model.ctx
    def spec(leaf):
        ax = ("batch",) + (None,) * (leaf.ndim - 1)
        return ctx.sharding(ax, leaf.shape)
    return jax.tree.map(spec, batch_abstract)


def make_train_step(model: LM, mesh=None):
    """Returns train_step(state, batch) -> (state, metrics). jit it with
    donate_argnums=(0,) and the sharding trees from state_shardings."""
    rcfg = model.rcfg
    ocfg = opt_config_from_run(rcfg)
    n_micro = max(rcfg.microbatch, 1)

    def loss_fn(params, batch):
        loss, _ = model.loss(params, batch)
        return loss

    def grads_plain(params, batch):
        if n_micro == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        B = batch["tokens"].shape[0]
        assert B % n_micro == 0, (B, n_micro)
        micro = jax.tree.map(
            lambda x: x.reshape(n_micro, B // n_micro, *x.shape[1:]), batch)

        def step(acc, mb):
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            acc = jax.tree.map(
                lambda a, gg: a + gg.astype(jnp.float32) / n_micro, acc, g)
            return acc, loss

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, losses = jax.lax.scan(step, zeros, micro)
        return jnp.mean(losses), grads

    compression = rcfg.grad_compression

    def train_step(state, batch):
        params = state["params"]
        if compression in ("posit8", "posit8_ef") and mesh is not None \
                and "pod" in mesh.axis_names:
            use_ef = compression == "posit8_ef"

            def per_pod(params, batch, ef):
                loss, grads = grads_plain(params, batch)
                grads, new_ef = compressed_grad_transform(
                    grads, "pod", N=8, ES=2, residuals=ef if use_ef else None)
                loss = jax.lax.pmean(loss, "pod")
                return loss, grads, (new_ef if use_ef else 0)

            ef_in = state.get("ef") if use_ef else None
            loss, grads, new_ef = shard_map_compat(
                per_pod, mesh,
                (P(), P("pod"), P()),
                (P(), P(), P()),
                manual_axes={"pod"},
            )(params, batch, ef_in)
        else:
            loss, grads = grads_plain(params, batch)
            new_ef = state.get("ef")
        new_params, new_opt, metrics = apply_updates(
            params, grads, state["opt"], ocfg)
        metrics["loss"] = loss
        new_state = {"params": new_params, "opt": new_opt}
        if "ef" in state:
            new_state["ef"] = new_ef
        return new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# CLI driver
# ---------------------------------------------------------------------------


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="yi-9b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--opt-quant", default="none", choices=["none", "posit8"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    from repro.data import DataConfig, synthetic_batch
    from repro.runtime import CheckpointManager, StepTimeMonitor

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = smoke_cfg(cfg)
    rcfg = RunConfig(learning_rate=args.lr, total_steps=args.steps,
                     warmup_steps=max(args.steps // 10, 1),
                     microbatch=args.microbatch, opt_state_quant=args.opt_quant,
                     remat="block")
    model = build_model(cfg, rcfg)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch)

    state = make_train_state(model, jax.random.PRNGKey(rcfg.seed))
    start = 0
    manager = None
    if args.ckpt_dir:
        manager = CheckpointManager(args.ckpt_dir, keep=3)
        latest = manager.latest_step()
        if latest is not None:
            print(f"resuming from step {latest}")
            state = manager.restore(latest)
            start = latest + 1

    step_fn = jax.jit(make_train_step(model), donate_argnums=(0,))
    mon = StepTimeMonitor()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in synthetic_batch(dc, step).items()}
        mon.start()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        ev = mon.stop()
        if ev:
            print(f"[straggler] step={ev.step} dur={ev.duration:.3f}s z={ev.zscore:.1f}")
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e}")
        if manager and (step % args.ckpt_every == 0 or step == args.steps - 1):
            manager.save(step, state)
    if manager:
        manager.wait()
    print(mon.report())


if __name__ == "__main__":
    main()

"""QuantPolicy API: spec grammar round-trip, rule precedence, apply_policy
equivalence with the legacy uniform path, mixed-policy consistency
(fxp_view / storage_bits), pareto-derived policies, quantized-checkpoint
round-trip with policy metadata."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, RunConfig, smoke
from repro.core.policy import (PRESETS, QuantPolicy, format_spec, parse_spec,
                               policy_from_pareto, storage_report)
from repro.core.quantizers import (QuantSpec, QuantizedTensor, dequantize,
                                   fxp_view, quantize, storage_bits)
from repro.nn.models import apply_policy, build_model, quantize_params

MIXED = "attn/*=pofx8es2,mlp/*=fxp8f7,*=bf16"


@pytest.fixture(scope="module")
def model_params():
    cfg = smoke(ARCHS["yi-9b"])
    model = build_model(cfg, RunConfig(remat="none"))
    return cfg, model, model.init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# spec grammar
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s", [
    "fp32", "bf16", "fxp8", "fxp8f7", "fxp16", "fxp7f6", "posit8es2",
    "posit6es1", "posit8", "pofx8es2", "pofx8", "pofx6es1m8-direct",
    "pofx8es2@tensor", "fxp8@none", "posit8es2@tensor", "keep",
])
def test_spec_string_roundtrip(s):
    spec = parse_spec(s)
    assert parse_spec(format_spec(spec)) == spec


def test_spec_defaults_match_legacy_presets():
    # the exact QuantSpecs serve.py's hand-rolled preset dict used to build
    assert parse_spec("pofx8") == QuantSpec(kind="pofx", N=8, ES=2, M=8)
    assert parse_spec("pofx8es2") == QuantSpec(kind="pofx", N=8, ES=2, M=8)
    assert parse_spec("fxp8") == QuantSpec(kind="fxp", M=8, F=7)
    assert parse_spec("posit8") == QuantSpec(kind="posit", N=8, ES=2)


def test_spec_fields():
    s = parse_spec("pofx6es1m8-direct")
    assert (s.kind, s.N, s.ES, s.M, s.path) == ("pofx", 6, 1, 8, "direct")
    assert parse_spec("pofx8es2@tensor").scale_mode == "tensor_pow2"
    assert parse_spec("fxp8f7") == parse_spec("fxp8")


@pytest.mark.parametrize("bad", ["pofx", "int8", "fxp8q3", "pofx8es2@bogus",
                                 "posit8-direct", ""])
def test_spec_parse_rejects_garbage(bad):
    with pytest.raises(ValueError):
        parse_spec(bad)


# ---------------------------------------------------------------------------
# policy rules
# ---------------------------------------------------------------------------


def test_policy_first_match_wins_and_fallback():
    p = QuantPolicy.from_string(
        "attn/wq=posit8es2,attn/*=pofx8es2,mlp/*=fxp8f7,*=bf16")
    assert p.match("blocks/attn/wq").kind == "posit"   # earlier rule wins
    assert p.match("blocks/attn/wo").kind == "pofx"
    assert p.match("blocks/mlp/wg").kind == "fxp"
    assert p.match("unembed").kind == "bf16"           # * fallback
    assert p.match("embed").kind == "bf16"


def test_policy_segment_anchoring():
    p = QuantPolicy.from_string("embed=bf16,attn/*=pofx8es2")
    assert p.match("embed") is not None
    assert p.match("unembed") is None            # no substring false-positive
    assert p.match("blocks/attn/wq") is not None  # implicit **/ prefix
    assert p.match("attn/wq") is not None
    assert p.match("blocks/mlp/wo") is None       # unmatched -> untouched


def test_policy_string_roundtrip_and_presets():
    p = QuantPolicy.from_string(MIXED)
    assert QuantPolicy.from_string(p.to_string()) == p
    uni = QuantPolicy.from_string("pofx8es2")
    assert uni.rules == (("*", parse_spec("pofx8es2")),)
    assert uni.to_string() == "pofx8es2"
    for name in PRESETS:
        pol = QuantPolicy.from_string(name)
        assert pol.rules[-1][0] == "*", name  # presets end in a fallback
    keep = QuantPolicy.from_string("embed=keep,*=fxp8")
    assert keep.match("embed") is None


# ---------------------------------------------------------------------------
# apply_policy on a stacked-blocks model
# ---------------------------------------------------------------------------


def test_uniform_policy_matches_legacy_quantize_params(model_params):
    _, _, params = model_params
    spec = QuantSpec(kind="pofx", N=8, ES=2, M=8)
    old = quantize_params(params, spec)
    new = apply_policy(params, "pofx8es2")
    for a, b in zip(jax.tree_util.tree_leaves(old),
                    jax.tree_util.tree_leaves(new)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quant_embed_false_shim(model_params):
    _, _, params = model_params
    qp = quantize_params(params, QuantSpec(kind="pofx", N=8, ES=2, M=8),
                         quant_embed=False)
    assert not isinstance(qp["embed"], QuantizedTensor)
    assert not isinstance(qp["unembed"], QuantizedTensor)
    assert isinstance(qp["blocks"]["attn"]["wq"], QuantizedTensor)


def test_never_quant_wins_over_rules(model_params):
    _, _, params = model_params
    qp = apply_policy(params, "*=fxp8")
    assert not isinstance(qp["ln_f"], QuantizedTensor)
    assert not isinstance(qp["blocks"]["ln1"], QuantizedTensor)


def test_mixed_policy_formats_and_stacked_scales(model_params):
    cfg, model, params = model_params
    qp = apply_policy(params, MIXED)
    wq = qp["blocks"]["attn"]["wq"]
    wg = qp["blocks"]["mlp"]["wg"]
    assert wq.spec.kind == "pofx" and wg.spec.kind == "fxp"
    # stacked leaves keep per-layer scales (leading layer dim mapped)
    assert wq.codes.shape[0] == cfg.n_layers
    assert wq.scale.shape[0] == cfg.n_layers
    assert qp["embed"].dtype == jnp.bfloat16  # bf16 rule casts, no wrapper
    logits = model.forward(qp, jnp.zeros((2, 8), jnp.int32))
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_fxp_view_storage_bits_consistent_under_mixed_policy(model_params):
    _, _, params = model_params
    qp = apply_policy(params, MIXED)
    seen = set()
    for leaf in jax.tree.leaves(
            qp, is_leaf=lambda x: isinstance(x, QuantizedTensor)):
        if not isinstance(leaf, QuantizedTensor):
            continue
        seen.add(leaf.spec.kind)
        n = int(np.prod(leaf.codes.shape))
        sn = int(np.prod(leaf.scale.shape))
        assert storage_bits(leaf) == n * leaf.spec.stored_bits + sn * 32
        codes, rescale = fxp_view(leaf)
        assert codes.dtype == jnp.int8
        # the int8 MAC view reconstructs the same values the LUT path sees
        np.testing.assert_allclose(
            np.asarray(codes, np.float32) * np.asarray(
                jnp.broadcast_to(rescale, codes.shape), np.float32),
            np.asarray(dequantize(leaf, jnp.float32)),
            rtol=1e-5, atol=1e-6)
    assert seen == {"pofx", "fxp"}


def test_storage_report_per_rule(model_params):
    _, _, params = model_params
    policy = QuantPolicy.from_string(MIXED)
    rep = storage_report(apply_policy(params, policy), policy)
    assert "attn/*=pofx8es2" in rep
    assert "mlp/*=fxp8" in rep
    assert "TOTAL" in rep


# ---------------------------------------------------------------------------
# pareto-driven policy search
# ---------------------------------------------------------------------------


def test_policy_from_pareto_picks_cheap_formats():
    rng = np.random.default_rng(0)
    groups = {
        "attn/*": [jnp.asarray(rng.normal(0, 0.05, (64, 32)), jnp.float32)],
        "mlp/*": [jnp.asarray(rng.normal(0, 0.02, (64, 64)), jnp.float32)],
    }
    pol = policy_from_pareto(groups, max_avg_rel=0.2, fallback="bf16")
    assert [r[0] for r in pol.rules] == ["attn/*", "mlp/*", "*"]
    for pat, spec in pol.rules[:-1]:
        assert spec.kind in ("fxp", "posit", "pofx")
        assert spec.stored_bits <= 16  # error budget met without fp32
    assert pol.rules[-1][1].kind == "bf16"
    QuantPolicy.from_string(pol.to_string())  # serializable


# ---------------------------------------------------------------------------
# quantized checkpoints
# ---------------------------------------------------------------------------


def test_checkpoint_quantized_roundtrip_with_policy(tmp_path, model_params):
    from repro.runtime import CheckpointManager

    _, _, params = model_params
    policy = QuantPolicy.from_string(MIXED)
    qp = apply_policy(params, policy)
    cm = CheckpointManager(str(tmp_path), keep=1, async_save=False)
    cm.save(3, {"params": qp}, policy=policy)
    assert cm.read_manifest()["quant_policy"] == policy.to_string()
    got = cm.restore()["params"]
    flat_a = jax.tree_util.tree_flatten(
        qp, is_leaf=lambda x: isinstance(x, QuantizedTensor))[0]
    flat_b = jax.tree_util.tree_flatten(
        got, is_leaf=lambda x: isinstance(x, QuantizedTensor))[0]
    n_qt = 0
    for a, b in zip(flat_a, flat_b):
        if isinstance(a, QuantizedTensor):
            n_qt += 1
            assert isinstance(b, QuantizedTensor)
            assert a.spec == b.spec  # grammar string round-trips the spec
            np.testing.assert_array_equal(np.asarray(a.codes),
                                          np.asarray(b.codes))
            np.testing.assert_array_equal(np.asarray(a.scale, np.float32),
                                          np.asarray(b.scale, np.float32))
        else:
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
    assert n_qt > 0


def test_checkpoint_packs_codes_at_stored_width(tmp_path):
    from repro.runtime import CheckpointManager

    w = jnp.asarray(np.linspace(-1, 1, 64 * 64).reshape(64, 64), jnp.float32)
    qt = quantize(w, parse_spec("pofx8es2"), axis=-1)   # 7-bit codes
    cm = CheckpointManager(str(tmp_path), keep=1, async_save=False)
    cm.save(1, {"params": {"w": qt}})
    import os
    root = os.path.join(str(tmp_path), "step_00000001")
    packed = os.path.getsize(os.path.join(root, "leaf_00000.npy"))
    # 4096 codes at 7 bits ~ 3584 bytes (+npy header), far below 1B/code
    assert packed < 4096 * 0.95
    got = cm.restore()["params"]["w"]
    np.testing.assert_array_equal(np.asarray(got.codes), np.asarray(qt.codes))
    # fxp (signed) codes survive the pack/sign-extend path too
    qf = quantize(w, parse_spec("fxp8"), axis=-1)
    cm.save(2, {"params": {"w": qf}})
    gf = cm.restore(step=2)["params"]["w"]
    assert int(np.asarray(qf.codes).min()) < 0
    np.testing.assert_array_equal(np.asarray(gf.codes), np.asarray(qf.codes))

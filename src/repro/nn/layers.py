"""Primitive layers: quantization-aware matmul, RMSNorm, rotary, MLPs.

Weights flow through every layer either as plain float arrays (training) or
as ``QuantizedTensor`` (post-training-quantized serving, the paper's mode).
``matmul_param`` dispatches: quantized weights go through the PoFx/FxP
datapath (XLA LUT path inside big jit graphs; Pallas kernels are validated
separately and selectable via use_kernel for eager serving).
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core.quantizers import QuantizedTensor, dequantize
from repro.kernels.ops import out_channel_scale, quant_matmul

Param = Union[jax.Array, QuantizedTensor]


def param_value(w: Param, dtype=jnp.bfloat16) -> jax.Array:
    """Dequantize (or cast) a parameter for direct elementwise use."""
    if isinstance(w, QuantizedTensor):
        return dequantize(w, dtype)
    return w.astype(dtype)


def matmul_param(x: jax.Array, w: Param, *, out_shape=None,
                 use_kernel: bool = False) -> jax.Array:
    """x:(..., k) @ w:(k, ...) with quantized-weight dispatch.

    ``w`` may have multiple output dims (e.g. (d_model, H, Dh)); pass
    ``out_shape`` to reshape the flattened output. Quantized weights must
    carry an out-channel scale layout — a scale varying along the
    contraction axis (codes axis 0) raises (see
    ``repro.kernels.ops.out_channel_scale``; DESIGN.md §2).
    """
    if isinstance(w, QuantizedTensor):
        k = w.codes.shape[0]
        codes2 = w.codes.reshape(k, -1)
        scale2 = out_channel_scale(w.scale, w.codes.shape)
        w2 = QuantizedTensor(codes2, scale2, w.spec)
        y = quant_matmul(x, w2, use_kernel=use_kernel)
        tail = w.codes.shape[1:]
    else:
        k = w.shape[0]
        y = jnp.dot(x, w.reshape(k, -1).astype(x.dtype),
                    preferred_element_type=jnp.float32).astype(x.dtype)
        tail = w.shape[1:]
    return y.reshape(*x.shape[:-1], *(out_shape or tail))


def rmsnorm(x: jax.Array, w: Param, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * param_value(w, jnp.float32)).astype(dt)


def rotary_cos_sin(positions: jax.Array, d_head: int, theta: float):
    """cos/sin tables for the given positions: (..., d_head//2)."""
    half = d_head // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, Dh); cos/sin: (B, S, Dh//2) -> broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name in ("gelu", "gelu_plain"):
        return jax.nn.gelu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


def is_gated(act: str) -> bool:
    return act in ("silu", "gelu")


def mlp_forward(p: dict, x: jax.Array, act: str, ctx, use_kernel: bool = False) -> jax.Array:
    """Gated (silu/gelu: wg,wu,wo) or plain (relu2/gelu_plain: wi,wo) MLP."""
    fn = activation(act)
    if is_gated(act):
        g = matmul_param(x, p["wg"], use_kernel=use_kernel)
        u = matmul_param(x, p["wu"], use_kernel=use_kernel)
        h = fn(g) * u
    else:
        h = fn(matmul_param(x, p["wi"], use_kernel=use_kernel))
    h = ctx.constrain(h, "batch", "seq_attn", "mlp")
    # down-proj is row-sharded under manual TP (contraction over the local
    # d_ff shard): the block's one MLP collective (DESIGN.md §9).
    return ctx.psum(matmul_param(h, p["wo"], use_kernel=use_kernel))


def dense_init(key, in_dim: int, out_dims, scale: Optional[float] = None,
               dtype=jnp.float32) -> jax.Array:
    out_dims = (out_dims,) if isinstance(out_dims, int) else tuple(out_dims)
    if scale is None:
        scale = in_dim ** -0.5
    return (jax.random.normal(key, (in_dim, *out_dims), dtype=jnp.float32)
            * scale).astype(dtype)


def mlp_init(key, d_model: int, d_ff: int, act: str, dtype=jnp.float32) -> dict:
    if is_gated(act):
        ks = jax.random.split(key, 3)
        return {
            "wg": dense_init(ks[0], d_model, d_ff, dtype=dtype),
            "wu": dense_init(ks[1], d_model, d_ff, dtype=dtype),
            "wo": dense_init(ks[2], d_ff, d_model, dtype=dtype),
        }
    ks = jax.random.split(key, 2)
    return {
        "wi": dense_init(ks[0], d_model, d_ff, dtype=dtype),
        "wo": dense_init(ks[1], d_ff, d_model, dtype=dtype),
    }


def mlp_logical(act: str) -> dict:
    if is_gated(act):
        return {"wg": ("p_embed", "mlp"), "wu": ("p_embed", "mlp"),
                "wo": ("mlp", "p_embed")}
    return {"wi": ("p_embed", "mlp"), "wo": ("mlp", "p_embed")}

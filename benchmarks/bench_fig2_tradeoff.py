"""Fig. 2: error / decode-cost / memory-footprint triple per scheme.

The FPGA CPD column becomes two measurable TPU analogues: static decode op
count (jaxpr primitive count — circuit-depth proxy) and measured CPU decode
wall-time per weight. Memory footprint is exact stored bits/weight.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.analysis import spec_name
from repro.core.policy import parse_spec
from repro.core.quantizers import quantize, storage_bits

from .common import (avg_abs_rel_error, decode_fn, jaxpr_ops,
                     vgg_like_weights, wall_time, write_csv)

# per-tensor pow2 normalizer (@tensor): the paper's "normalized parameters"
# assumption (one scale per tensor, negligible overhead)
SPEC_STRINGS = ("fp32", "bf16", "fxp8@tensor", "fxp16@tensor",
                "posit8es2@tensor", "posit6es2@tensor",
                "pofx8es2@tensor", "pofx6es2@tensor")


def run(extra_specs=(), smoke: bool = False):
    size = 1 << 13 if smoke else 1 << 18
    w = vgg_like_weights(size)
    rows = []
    # extra specs get the same per-tensor normalizer unless one is named
    # explicitly — this bench's weight buffer is 1-D, where the default
    # channel scale degenerates to one fp32 scale per weight.
    extras = tuple(s if "@" in s else s + "@tensor" for s in extra_specs)
    specs = [parse_spec(s) for s in (*SPEC_STRINGS, *extras)]
    codes8 = jnp.asarray(np.random.default_rng(0).integers(0, 128, size),
                         jnp.int32)
    for spec in specs:
        name = spec_name(spec)
        qt = quantize(jnp.asarray(w, jnp.float32), spec)
        wq = np.asarray(qt.dequantize(jnp.float32), np.float64)
        row = {"scheme": name,
               "avg_rel": avg_abs_rel_error(w, wq),
               "bits_per_weight": storage_bits(qt) / w.size}
        fn = decode_fn(spec)
        if fn is not None:
            row["decode_ops"] = jaxpr_ops(fn, codes8)
            row["decode_ns_per_weight"] = wall_time(fn, codes8) / codes8.size * 1e9
        else:
            row["decode_ops"] = 0
            row["decode_ns_per_weight"] = 0.0
        rows.append(row)
    write_csv("fig2_tradeoff", rows)
    by = {r["scheme"]: r for r in rows}
    return rows, {
        # paper Fig 2: posit decode much deeper than fxp; pofx storage wins
        "pofx7_bits": by["pofx(7,2,via_fxp)"]["bits_per_weight"],
        "fxp8_bits": by["fxp8"]["bits_per_weight"],
        "posit_decode_deeper_than_fxp":
            by["posit(8,2)"]["decode_ops"] > by["fxp8"]["decode_ops"],
    }

"""Pallas kernel tests: shape/dtype sweeps against the ref.py oracles
(interpret mode on CPU), per the deliverable-(c) contract."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import QuantSpec, quantize
from repro.core.quantizers import kv_quantize
from repro.kernels import (default_blocks, fxp_matmul, kv_flash_decode,
                           pofx_decode, pofx_matmul, quant_matmul)
from repro.kernels.ref import (decode_norm_to_fxp, fxp_matmul_ref,
                               kv_flash_decode_ref, pofx_decode_ref,
                               pofx_matmul_ref)
from proptest import Floats, given

RNG = np.random.default_rng(1234)

DECODE_SHAPES = [(8, 8), (100, 100), (256, 512), (33, 257), (1, 128), (512, 64)]
POSIT_CONFIGS = [(8, 2), (8, 0), (6, 1), (7, 3), (5, 0), (9, 2)]
MM_SHAPES = [(16, 32, 24), (64, 200, 300), (128, 128, 128), (7, 65, 130), (1, 256, 16)]
KV_SPECS = [QuantSpec(kind="fxp", M=8, F=7), QuantSpec(kind="fxp", M=8, F=4),
            QuantSpec(kind="pofx", N=8, ES=2), QuantSpec(kind="pofx", N=6, ES=1)]


@pytest.mark.parametrize("shape", DECODE_SHAPES)
@pytest.mark.parametrize("N,ES", POSIT_CONFIGS[:3])
def test_pofx_decode_kernel_exact(shape, N, ES):
    codes = jnp.asarray(RNG.integers(0, 1 << (N - 1), size=shape), dtype=jnp.uint8)
    out = pofx_decode(codes, N, ES, 8, block=(64, 128))
    ref = pofx_decode_ref(codes, N, ES, 8)
    assert out.dtype == jnp.int8
    assert np.array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("N,ES", POSIT_CONFIGS)
def test_pofx_decode_kernel_all_codes(N, ES):
    """Every code value flows through the kernel identically to Algorithm 1."""
    all_codes = np.arange(1 << (N - 1), dtype=np.uint8)
    tile = np.tile(all_codes, (8, 2))  # 2D for BlockSpec
    out = pofx_decode(jnp.asarray(tile), N, ES, 8, block=(8, 64))
    ref = pofx_decode_ref(jnp.asarray(tile), N, ES, 8)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("m,k,n", MM_SHAPES)
@pytest.mark.parametrize("mode", ["bitlevel", "onehot"])
def test_pofx_matmul_kernel(m, k, n, mode):
    x = jnp.asarray(RNG.standard_normal((m, k)).astype(np.float32))
    codes = jnp.asarray(RNG.integers(0, 128, size=(k, n)), dtype=jnp.uint8)
    scale = jnp.asarray((np.abs(RNG.standard_normal(n)) + 0.1).astype(np.float32))
    y = pofx_matmul(x, codes, scale, 8, 2, 8, blocks=(32, 128, 64), decode_mode=mode)
    ref = pofx_matmul_ref(x, codes, scale, 8, 2, 8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pofx_matmul_activation_dtypes(dtype):
    x = jnp.asarray(RNG.standard_normal((32, 64)).astype(np.float32)).astype(dtype)
    codes = jnp.asarray(RNG.integers(0, 128, size=(64, 48)), dtype=jnp.uint8)
    scale = jnp.ones((48,), jnp.float32)
    y = pofx_matmul(x, codes, scale, 8, 2, 8, blocks=(32, 48, 64))
    ref = pofx_matmul_ref(x, codes, scale, 8, 2, 8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5,
                               atol=1e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("N,ES", POSIT_CONFIGS)
@pytest.mark.parametrize("M", [4, 8, 12, 16])
def test_pad_code_zero_decodes_to_zero(N, ES, M):
    """Regression for the matmul kernels' zero padding: ``pofx_matmul``
    pads code tiles with 0 on the claim that code 0 decodes to value 0 (so
    padded K-dim tiles contribute nothing to the accumulator), and
    ``kv_flash_decode`` zero-pads ragged S tiles the same way. A LUT or
    bit-level decode change that broke this would silently corrupt every
    padded tile — pin it across the supported (N, ES, M) grid."""
    zero = jnp.zeros((1, 1), jnp.int32)
    assert int(decode_norm_to_fxp(zero, N, ES, M)[0, 0]) == 0


def test_default_blocks_table():
    # every backend entry is a 3-tuple; the active backend resolves
    for backend in ("tpu", "cpu", "gpu"):
        assert len(default_blocks(backend)) == 3
    assert default_blocks("unknown-backend") == default_blocks("tpu")
    assert len(default_blocks()) == 3


@pytest.mark.parametrize("spec", KV_SPECS, ids=lambda s: f"{s.kind}{s.N if s.kind=='pofx' else s.M}")
@pytest.mark.parametrize("block_s", [8, 16, 64])
def test_kv_flash_decode_matches_ref(spec, block_s):
    """Fused kernel vs the XLA dequantize-on-read oracle, ragged per-slot
    positions included (masked tail + zero-padded tiles)."""
    rng = np.random.default_rng(11)
    B, G, R, Dh, S = 3, 2, 4, 32, 40
    q = jnp.asarray(rng.standard_normal((B, G, R, Dh)), jnp.float32)
    ks = jnp.asarray(np.exp2(rng.integers(-1, 3, (B, G, 1, Dh))), jnp.float32)
    vs = jnp.asarray(np.exp2(rng.integers(-2, 2, (B, G, 1, Dh))), jnp.float32)
    kc = kv_quantize(jnp.asarray(rng.standard_normal((B, G, S, Dh)),
                                 jnp.float32), spec, ks)
    vc = kv_quantize(jnp.asarray(rng.standard_normal((B, G, S, Dh)),
                                 jnp.float32), spec, vs)
    pos = jnp.asarray([1, 17, 40], jnp.int32)   # ragged, incl. full cache
    out = kv_flash_decode(q, kc, ks, vc, vs, pos, spec, block_s=block_s)
    ref = kv_flash_decode_ref(q, kc, ks, vc, vs, pos, spec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_kv_flash_decode_scalar_pos_and_shape_guards():
    spec = QuantSpec(kind="fxp", M=8, F=7)
    rng = np.random.default_rng(5)
    B, G, R, Dh, S = 2, 1, 2, 16, 12
    q = jnp.asarray(rng.standard_normal((B, G, R, Dh)), jnp.float32)
    ones = jnp.ones((B, G, 1, Dh), jnp.float32)
    kc = kv_quantize(jnp.asarray(rng.standard_normal((B, G, S, Dh)),
                                 jnp.float32), spec, ones)
    vc = kv_quantize(jnp.asarray(rng.standard_normal((B, G, S, Dh)),
                                 jnp.float32), spec, ones)
    out = kv_flash_decode(q, kc, ones, vc, ones, jnp.asarray(7), spec,
                          block_s=4)
    ref = kv_flash_decode_ref(q, kc, ones, vc, ones, jnp.asarray(7), spec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError, match="k_scale"):
        kv_flash_decode(q, kc, jnp.ones((B, G, S, Dh)), vc, ones,
                        jnp.asarray(7), spec)
    with pytest.raises(ValueError, match="v_scale"):
        kv_flash_decode(q, kc, ones, vc, jnp.ones((B, G, S, Dh)),
                        jnp.asarray(7), spec)
    with pytest.raises(ValueError, match="mismatch"):
        kv_flash_decode(q, kc, ones, vc[:, :, :-1], ones, jnp.asarray(7),
                        spec)


@pytest.mark.parametrize("m,k,n", MM_SHAPES)
def test_fxp_matmul_kernel_exact(m, k, n):
    a = jnp.asarray(RNG.integers(-127, 128, size=(m, k)), dtype=jnp.int8)
    b = jnp.asarray(RNG.integers(-127, 128, size=(k, n)), dtype=jnp.int8)
    out = fxp_matmul(a, b, blocks=(32, 64, 32))
    assert out.dtype == jnp.int32
    assert np.array_equal(np.asarray(out), np.asarray(fxp_matmul_ref(a, b)))


def test_fxp_matmul_accumulator_headroom():
    """Worst-case accumulation must not overflow int32 (3M-bit argument)."""
    k = 4096  # 127*127*4096 ~ 2^26*4096/64 ... = 6.6e7 << 2^31
    a = jnp.full((8, k), 127, jnp.int8)
    b = jnp.full((k, 8), 127, jnp.int8)
    out = fxp_matmul(a, b, blocks=(8, 8, 512))
    assert int(out[0, 0]) == 127 * 127 * k


@given(seed=5, examples=10, x=Floats(lo=-2, hi=2, shape=(16, 96)))
def test_property_quant_matmul_close_to_float(x):
    """Property: pofx kernel matmul approximates the float matmul with error
    bounded by the quantization error times activation norm."""
    w = (np.random.default_rng(0).standard_normal((96, 32)) * 0.1).astype(np.float32)
    xq = jnp.asarray(x.astype(np.float32))
    qt = quantize(jnp.asarray(w), QuantSpec(kind="pofx", N=8, ES=2), axis=-1)
    y_kernel = quant_matmul(xq, qt, use_kernel=True)
    y_float = xq @ w
    denom = np.maximum(np.abs(np.asarray(y_float)), 1.0)
    rel = np.abs(np.asarray(y_kernel) - np.asarray(y_float)) / denom
    assert rel.mean() < 0.05


def test_quant_matmul_kernel_equals_xla_path():
    x = jnp.asarray(RNG.standard_normal((10, 64)).astype(np.float32))
    w = jnp.asarray((RNG.standard_normal((64, 80)) * 0.05).astype(np.float32))
    qt = quantize(w, QuantSpec(kind="pofx", N=8, ES=2), axis=-1)
    yk = quant_matmul(x, qt, use_kernel=True)
    yx = quant_matmul(x, qt, use_kernel=False)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yx), rtol=1e-4, atol=1e-4)


def test_quant_matmul_batched_leading_dims():
    x = jnp.asarray(RNG.standard_normal((2, 3, 64)).astype(np.float32))
    w = jnp.asarray((RNG.standard_normal((64, 32)) * 0.1).astype(np.float32))
    qt = quantize(w, QuantSpec(kind="pofx", N=8, ES=2), axis=-1)
    y = quant_matmul(x, qt, use_kernel=True)
    assert y.shape == (2, 3, 32)

"""Public jit'd entry points for the kernels, with automatic dispatch.

``quant_matmul`` is what the model layers call: given activations and a
QuantizedTensor weight it picks the right datapath —

  pofx   + use_kernel   -> fused Pallas decode+matmul (Move & Store)
  pofx   + no kernel    -> LUT dequantize + XLA matmul (Move; decode at load)
  fxp    + int8 acts    -> int8 MXU MAC (fxp_matmul)
  others                -> dequantize + XLA matmul

On this CPU container kernels run in interpret mode; on TPU they compile to
Mosaic. ``use_kernel="auto"`` keeps kernels out of huge jit graphs (the
dry-run lowers the XLA path; kernels are validated separately).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantizers import QuantizedTensor, dequantize, fxp_view
from .fxp_matmul import fxp_matmul
from .pofx_decode import pofx_decode
from .pofx_matmul import pofx_matmul

__all__ = ["quant_matmul", "out_channel_scale", "pofx_decode", "pofx_matmul",
           "fxp_matmul"]


def out_channel_scale(scale: jax.Array, codes_shape) -> jax.Array:
    """Validate a QuantizedTensor scale layout and collapse it to (1, n).

    Every quantized-matmul datapath folds the normalizer in *after* the
    contraction — y = (x @ decode(codes)) * scale — which is only sound
    when the scale is constant along the contraction axis (codes axis 0):
    per-output-channel, per-tensor, or any broadcast shape that never
    covers axis 0. A scale that varies along the contraction axis would
    need the rescale inside the MAC loop, which no kernel implements, so
    it raises instead of silently keeping row 0 of the flattened scale
    (the old corrupting behavior). NumPy broadcasting aligns trailing
    dims, so axis 0 is covered iff scale.ndim == codes.ndim.
    """
    sshape = tuple(getattr(scale, "shape", ()))
    if len(sshape) > len(codes_shape):
        raise ValueError(
            f"scale rank {len(sshape)} exceeds codes rank {len(codes_shape)} "
            f"(scale {sshape} vs codes {tuple(codes_shape)})")
    if len(sshape) == len(codes_shape) and sshape[0] != 1:
        raise ValueError(
            f"unsupported scale layout {sshape} for codes "
            f"{tuple(codes_shape)}: the scale varies along the contraction "
            "axis (codes axis 0); quantized matmuls apply the normalizer "
            "after the contraction, so only per-output-channel or "
            "per-tensor scales are representable")
    try:
        out = jnp.broadcast_to(scale, (1, *codes_shape[1:]))
    except ValueError as e:
        raise ValueError(
            f"scale shape {sshape} does not broadcast against the output "
            f"dims of codes {tuple(codes_shape)}: {e}") from None
    return out.reshape(1, -1)


def quant_matmul(x: jax.Array, w: QuantizedTensor, *,
                 use_kernel: bool = False,
                 out_dtype=None) -> jax.Array:
    """x @ dequant(w); x: (..., k), w codes: (k, n).

    The kernel paths require an out-channel scale layout (see
    ``out_channel_scale``); the dequantize fallback is mathematically
    general and stays permissive.
    """
    out_dtype = out_dtype or x.dtype
    spec = w.spec
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    if spec.kind == "pofx" and use_kernel:
        scale = out_channel_scale(w.scale, w.codes.shape).reshape(-1)
        y = pofx_matmul(x2, w.codes, scale, spec.N, spec.ES, spec.M)
        return y.reshape(*lead, -1).astype(out_dtype)
    if spec.kind == "fxp" and use_kernel:
        codes, rescale = fxp_view(w)
        rescale = out_channel_scale(rescale, w.codes.shape)
        # int8 activations: per-tensor symmetric quantization of x.
        xmax = jnp.maximum(jnp.max(jnp.abs(x2)), 1e-6)
        xq = jnp.clip(jnp.round(x2 / xmax * 127.0), -127, 127).astype(jnp.int8)
        acc = fxp_matmul(xq, codes)
        y = acc.astype(jnp.float32) * (xmax / 127.0) * rescale
        return y.reshape(*lead, -1).astype(out_dtype)
    wv = dequantize(w, jnp.bfloat16 if out_dtype == jnp.bfloat16 else jnp.float32)
    y = jnp.dot(x2.astype(wv.dtype), wv, preferred_element_type=jnp.float32)
    return y.reshape(*lead, -1).astype(out_dtype)

import os
import sys

# Tests run on the single real CPU device; the 512-device dry-run sets its
# own XLA_FLAGS in a separate process (see launch/dryrun.py).
sys.path.insert(0, os.path.dirname(__file__))

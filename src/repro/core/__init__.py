"""repro.core — ExPAN(N)D numerics: posit, normalized posit, PoFx, FxP.

The paper's primary contribution lives here: the Posit(N,ES) codec, the
normalized (N-1)-bit representation, the bit-faithful PoFx converter
(Algorithm 1), FxP linear quantization, the composite quantization paths,
and the behavioral-analysis / Pareto machinery of Fig. 8 and Tables 3-6.
"""
from .posit import (  # noqa: F401
    NAR,
    posit_decode,
    posit_decode_np,
    posit_encode,
    posit_encode_np,
    posit_max,
    posit_min_pos,
    posit_value_table,
)
from .normalized_posit import (  # noqa: F401
    norm_compress,
    norm_decode,
    norm_decode_np,
    norm_encode,
    norm_encode_np,
    norm_expand,
    norm_max,
    pack_bits,
    unpack_bits,
)
from .pofx import (  # noqa: F401
    pofx_convert,
    pofx_convert_np,
    pofx_lut,
    pofx_norm_lut,
    pofx_normalized,
    pofx_normalized_np,
)
from .fxp import (  # noqa: F401
    compute_scale,
    fxp_dequantize,
    fxp_dequantize_np,
    fxp_quantize,
    fxp_quantize_np,
)
from .quantizers import (  # noqa: F401
    QuantSpec,
    QuantizedTensor,
    dequantize,
    fxp_view,
    quantize,
    storage_bits,
)
from .pareto import hypervolume, hypervolume_gain, pareto_front, pareto_mask  # noqa: F401
from .policy import (  # noqa: F401
    PRESETS,
    QuantPolicy,
    add_policy_arg,
    format_spec,
    parse_spec,
    policy_from_pareto,
    storage_report,
)

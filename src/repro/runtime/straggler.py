"""Straggler detection: robust step-time outlier flagging.

At 1000+ nodes, slow hosts show up as step-time outliers (every step is a
barrier). The monitor keeps a rolling window of step durations and flags
steps whose modified z-score (median/MAD — robust to the slow tail it is
trying to detect) exceeds a threshold. The launcher logs flags and, above
``abort_ratio``, recommends a checkpoint-restart excluding the slow host —
the standard mitigation when a VM is degraded rather than dead.
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
import time
from typing import List, Optional

__all__ = ["StepTimeMonitor"]


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    zscore: float


class StepTimeMonitor:
    def __init__(self, window: int = 64, z_threshold: float = 4.0,
                 abort_ratio: float = 3.0, warmup: int = 8):
        self.window = collections.deque(maxlen=window)
        self.z_threshold = z_threshold
        self.abort_ratio = abort_ratio
        self.warmup = warmup
        self.events: List[StragglerEvent] = []
        self._t0: Optional[float] = None
        self._step = 0

    def start(self) -> None:
        self._t0 = time.perf_counter()

    def stop(self) -> Optional[StragglerEvent]:
        assert self._t0 is not None, "stop() without start()"
        dur = time.perf_counter() - self._t0
        self._t0 = None
        return self.record(self._step, dur)

    def record(self, step: int, duration: float) -> Optional[StragglerEvent]:
        self._step = step + 1
        ev = None
        if len(self.window) >= self.warmup:
            med = statistics.median(self.window)
            mad = statistics.median(abs(d - med) for d in self.window) or 1e-9
            z = 0.6745 * (duration - med) / mad
            if z > self.z_threshold:
                ev = StragglerEvent(step, duration, z)
                self.events.append(ev)
        # slow samples are *not* added to the window (keep the baseline clean)
        if ev is None:
            self.window.append(duration)
        return ev

    def should_restart(self) -> bool:
        """True when recent steps are consistently >abort_ratio x median."""
        if len(self.window) < self.warmup or len(self.events) < 3:
            return False
        med = statistics.median(self.window)
        recent = self.events[-3:]
        return all(e.duration > self.abort_ratio * med for e in recent)

    def report(self) -> str:
        med = statistics.median(self.window) if self.window else float("nan")
        return (f"steps={self._step} median={med:.4f}s "
                f"stragglers={len(self.events)} restart={self.should_restart()}")

"""Serving driver: prefill a batch of prompts, decode with donated cache.

Demonstrates the paper's deployment story end to end on real (CPU-sized)
shapes: weights post-training-quantized to normalized Posit(N-1,ES) codes
(PoFx Move&Store), the KV cache donated and updated in place, greedy
decode. Prints tokens/s and the parameter-storage footprint vs bf16/fp32
(the paper's Table 6 storage row, measured on the actual pytree).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
        --quant pofx8 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, RunConfig, smoke as smoke_cfg
from repro.core.quantizers import QuantSpec, QuantizedTensor, storage_bits
from repro.nn.models import build_model, quantize_params


def param_storage_report(params) -> str:
    total_bits = 0
    total_n = 0
    for leaf in jax.tree.leaves(
            params, is_leaf=lambda x: isinstance(x, QuantizedTensor)):
        if isinstance(leaf, QuantizedTensor):
            total_bits += storage_bits(leaf)
            total_n += int(np.prod(leaf.codes.shape))
        else:
            total_bits += leaf.size * leaf.dtype.itemsize * 8
            total_n += leaf.size
    bpw = total_bits / max(total_n, 1)
    return (f"params={total_n/1e6:.1f}M stored={total_bits/8/2**20:.1f}MiB "
            f"({bpw:.2f} bits/weight; vs fp32 {32/bpw:.1f}x, "
            f"vs bf16 {16/bpw:.1f}x smaller)")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="yi-9b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--quant", default="pofx8",
                    choices=["bf16", "fxp8", "pofx8", "posit8"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = smoke_cfg(cfg)
    rcfg = RunConfig(remat="none")
    model = build_model(cfg, rcfg)
    params = model.init(jax.random.PRNGKey(0))
    if args.quant != "bf16":
        spec = {"pofx8": QuantSpec(kind="pofx", N=8, ES=2, M=8),
                "fxp8": QuantSpec(kind="fxp", M=8, F=7),
                "posit8": QuantSpec(kind="posit", N=8, ES=2)}[args.quant]
        params = quantize_params(params, spec)
    print(f"[{args.arch} quant={args.quant}] {param_storage_report(params)}")

    B, P = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size)
    frames = None
    if cfg.family == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(2), (B, P, cfg.d_model),
                                   jnp.float32)
    max_len = P + args.gen + 1
    cache = model.init_cache(B, max_len, enc_len=P)

    t0 = time.perf_counter()
    cache, logits = jax.jit(
        lambda p, c, t: model.prefill(p, t, cache=c, frames=frames),
        donate_argnums=(1,))(params, cache, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    tok = jnp.argmax(logits, axis=-1)[:, None]
    outs = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen):
        cache, logits = decode(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1)[:, None]
        outs.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.asarray(jnp.concatenate(outs, axis=1))
    assert not np.any(np.isnan(np.asarray(logits))), "NaN logits"
    print(f"prefill: {B}x{P} tokens in {t_prefill:.3f}s "
          f"({B*P/t_prefill:.0f} tok/s)")
    print(f"decode:  {args.gen} steps x {B} seqs in {t_decode:.3f}s "
          f"({args.gen*B/t_decode:.1f} tok/s)")
    print("sample:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()

"""Table 6: joint accuracy x hardware-cost view of the surviving configs.

Combines bench_table5's accuracies with storage bits/weight and decode op
counts (PDP/LUT analogues) for the feasible configurations, mirroring the
paper's joint table; the §Claims row checks PoFx configs reach FxP8-class
accuracy with fewer stored bits.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.policy import parse_spec
from repro.core.quantizers import quantize, storage_bits
from repro.core.analysis import spec_name

from .common import decode_fn, jaxpr_ops, vgg_like_weights, write_csv
from . import bench_table5_accuracy as t5


def run(extra_specs=(), smoke: bool = False):
    acc_rows, _ = t5.run(extra_specs=extra_specs, smoke=smoke)
    acc = {r["config"]: r["accuracy"] for r in acc_rows}
    w = vgg_like_weights(1 << 11 if smoke else 1 << 14)
    codes = jnp.asarray(np.arange(256 if smoke else 4096) % 32, jnp.int32)
    rows = []

    def cost(spec):
        import dataclasses
        # per-tensor pow2 normalizer for the cost model (paper assumption)
        if spec.kind not in ("fp32", "bf16"):
            spec = dataclasses.replace(spec, scale_mode="tensor_pow2")
        qt = quantize(jnp.asarray(w, jnp.float32), spec)
        bits = storage_bits(qt) / w.size
        fn = decode_fn(spec)
        ops = jaxpr_ops(fn, codes) if fn is not None else 0
        return bits, ops

    spec_strings = ["fxp16", "fxp8"]
    spec_strings += [f"posit{N}es{ES}" for N in (7, 8) for ES in (1, 2, 3)]
    spec_strings += [f"pofx{N}es{ES}" for N in (6, 7, 8) for ES in (1, 2)]
    spec_strings += list(extra_specs)
    for spec in map(parse_spec, spec_strings):
        name = spec_name(spec)
        bits, ops = cost(spec)
        rows.append({"config": name, "accuracy": acc.get(name, float("nan")),
                     "bits_per_weight": bits, "decode_ops": ops})
    write_csv("table6_joint", rows)
    by = {r["config"]: r for r in rows}
    pofx72 = by["pofx(7,2,via_fxp)"]
    fxp8 = by["fxp8"]
    return rows, {
        "pofx72_bits": pofx72["bits_per_weight"],
        "fxp8_bits": fxp8["bits_per_weight"],
        "pofx72_acc": pofx72["accuracy"],
        "fxp8_acc": fxp8["accuracy"],
        "claim_pofx_matches_fxp8_acc_with_fewer_bits":
            bool(pofx72["accuracy"] >= fxp8["accuracy"] - 0.01
                 and pofx72["bits_per_weight"] < fxp8["bits_per_weight"]),
    }

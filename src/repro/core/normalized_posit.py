"""Normalized Posit — the ExPAN(N)D (N-1)-bit storage representation.

Paper §4.1.1 / Table 2: an N-bit Posit pattern representing a *normalized*
number (|value| <= 1; positive sub-unit values lead with ``00``, negative
with ``11``) always has its two leading bits identical.  ExPAN(N)D drops the
duplicated bit and stores N-1 bits; decode replicates the MSB.

Code layout of a stored normalized posit ``b_{N-2} ... b_0``:
  expand -> posit = [b_{N-2}, b_{N-2}, b_{N-3}, ..., b_0]   (N bits)

Monotonicity note: posit codes order like two's-complement integers, so
clamping a signed code into [-(2^(N-2)), 2^(N-2)-1] saturates exactly onto the
normalized sub-lattice ([-1, largest-posit-below-1]).

Also provides true k-bit packing (``pack_bits``/``unpack_bits``) used for
checkpoint storage, DCN transfer accounting and the paper's storage claims.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .posit import posit_decode, posit_decode_np, posit_encode, posit_encode_np

__all__ = [
    "norm_expand",
    "norm_compress",
    "norm_encode",
    "norm_encode_np",
    "norm_decode",
    "norm_decode_np",
    "norm_max",
    "pack_bits",
    "unpack_bits",
]


def norm_expand(codes, N: int):
    """(N-1)-bit normalized code -> N-bit posit code (replicate MSB)."""
    xp = jnp if isinstance(codes, jax.Array) else np
    c = xp.asarray(codes).astype(xp.int32) & ((1 << (N - 1)) - 1)
    s = (c >> (N - 2)) & 1
    lower = c & ((1 << (N - 2)) - 1)
    return (s << (N - 1)) | (s << (N - 2)) | lower


def norm_compress(codes, N: int):
    """N-bit posit code -> (N-1)-bit normalized code (drop duplicated bit).

    Callers must ensure codes lie in the normalized sub-lattice (leading two
    bits equal); ``norm_encode`` guarantees this via signed-code clamping.
    """
    xp = jnp if isinstance(codes, jax.Array) else np
    c = xp.asarray(codes).astype(xp.int32) & ((1 << N) - 1)
    s = (c >> (N - 1)) & 1
    lower = c & ((1 << (N - 2)) - 1)
    return (s << (N - 2)) | lower


def _signed_clamp(codes, N: int, xp):
    """Clamp raw N-bit posit codes (as signed ints) onto the normalized range."""
    c = xp.asarray(codes).astype(xp.int32) & ((1 << N) - 1)
    signed = xp.where(c >= (1 << (N - 1)), c - (1 << N), c)
    lo = -(1 << (N - 2))          # code of -1.0
    hi = (1 << (N - 2)) - 1       # largest posit < 1.0
    signed = xp.clip(signed, lo, hi)
    return signed & ((1 << N) - 1)


def norm_encode_np(x, N: int, ES: int) -> np.ndarray:
    full = posit_encode_np(x, N, ES)
    return norm_compress(_signed_clamp(full, N, np), N)


def norm_encode(x, N: int, ES: int) -> jax.Array:
    full = posit_encode(x, N, ES)
    return norm_compress(_signed_clamp(full, N, jnp), N)


def norm_encode_arith(x, N: int, ES: int) -> jax.Array:
    """Gather-free normalized-posit encode (bit-arithmetic RNE; see
    posit_encode_arith). Partition-safe inside shard_map manual axes."""
    from .posit import posit_encode_arith
    full = posit_encode_arith(x, N, ES)
    return norm_compress(_signed_clamp(full, N, jnp), N)


def norm_decode_np(codes, N: int, ES: int) -> np.ndarray:
    return posit_decode_np(norm_expand(codes, N), N, ES)


def norm_decode(codes, N: int, ES: int) -> jax.Array:
    return posit_decode(norm_expand(codes, N), N, ES)


def norm_max(N: int, ES: int) -> float:
    """Largest representable normalized-posit magnitude (< 1)."""
    return float(norm_decode_np(np.asarray([(1 << (N - 2)) - 1]), N, ES)[0])


# ---------------------------------------------------------------------------
# True k-bit packing (numpy; storage-side only — kernels read byte-aligned
# codes, checkpoints/DCN use packed streams).
# ---------------------------------------------------------------------------

def pack_bits(codes: np.ndarray, k: int) -> np.ndarray:
    """Pack int codes (< 2^k) into a uint8 byte stream, MSB-first."""
    flat = np.asarray(codes).astype(np.uint32).reshape(-1)
    bits = ((flat[:, None] >> np.arange(k - 1, -1, -1, dtype=np.uint32)) & 1).astype(np.uint8)
    return np.packbits(bits.reshape(-1))


def unpack_bits(packed: np.ndarray, k: int, count: int) -> np.ndarray:
    """Inverse of pack_bits: recover ``count`` k-bit codes."""
    bits = np.unpackbits(np.asarray(packed, dtype=np.uint8))[: count * k]
    bits = bits.reshape(count, k).astype(np.uint32)
    weights = (1 << np.arange(k - 1, -1, -1, dtype=np.uint32))
    return (bits * weights).sum(axis=1).astype(np.int32)

"""Checkpointing: atomic, async, keep-k, posit-compressed, elastic.

Layout (one directory per step, atomically renamed into place):

    <dir>/step_00000420/
        manifest.json      step, leaf count, shapes/dtypes, compression info
        treedef.pkl        pytree structure (includes QuantSpec statics)
        leaf_00000.npy ... one file per pytree leaf (raw or posit-packed)

Fault-tolerance contract:
  * atomicity — writes land in ``<dir>/.tmp_<step>`` and are renamed only
    after every file is fsynced; a crash mid-save never corrupts the latest
    valid checkpoint (restore scans for the newest complete manifest).
  * async — ``save`` snapshots to host memory synchronously (the step can
    proceed) and does disk I/O on a background thread; ``wait()`` joins.
  * keep-k GC — older step dirs are deleted after a successful save.
  * elastic restore — leaves are stored unsharded; ``restore`` device_puts
    onto whatever sharding tree the *current* mesh dictates, so a relaunch
    on a different pod/slice count resumes seamlessly.
  * posit compression (the paper's storage claim applied to checkpoints) —
    float leaves under the top-level ``params`` key are stored as
    bit-packed normalized Posit(N-1,ES) codes + per-channel scale when a
    QuantSpec is supplied: 7 bits/weight vs 32 (fp32) is a 4.6x smaller
    checkpoint, the Table-6 storage row at rest.
"""
from __future__ import annotations

import json
import os
import pickle
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.normalized_posit import (norm_decode_np, norm_encode_np,
                                         pack_bits, unpack_bits)
from repro.core.quantizers import QuantSpec

__all__ = ["CheckpointManager"]


def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype string, including ml_dtypes names (bfloat16 etc.)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _reinterpret(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    """np.save round-trips ml_dtypes arrays as void bytes; view them back."""
    want = _np_dtype(dtype_name)
    if arr.dtype != want and arr.dtype.kind == "V":
        return arr.view(want)
    return arr


def _is_param_path(path) -> bool:
    first = path[0]
    key = getattr(first, "key", getattr(first, "name", None))
    return key == "params"


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------

    def save(self, step: int, state: Any,
             param_compress: Optional[QuantSpec] = None) -> None:
        self.wait()
        flat, treedef = jax.tree_util.tree_flatten_with_path(state)
        host_leaves = []
        for path, leaf in flat:
            arr = np.asarray(jax.device_get(leaf))
            compress = (param_compress is not None and _is_param_path(path)
                        and np.issubdtype(arr.dtype, np.floating)
                        and arr.ndim >= 2)
            host_leaves.append((arr, compress))
        payload = (step, treedef, host_leaves, param_compress)
        if self.async_save:
            self._thread = threading.Thread(target=self._write, args=payload)
            self._thread.start()
        else:
            self._write(*payload)

    def _write(self, step, treedef, host_leaves, spec) -> None:
        tmp = os.path.join(self.dir, f".tmp_{step:08d}")
        final = os.path.join(self.dir, f"step_{step:08d}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest: Dict[str, Any] = {"step": step, "leaves": []}
        for i, (arr, compress) in enumerate(host_leaves):
            name = f"leaf_{i:05d}.npy"
            entry = {"file": name, "shape": list(arr.shape),
                     "dtype": str(arr.dtype), "compressed": bool(compress)}
            if compress:
                N, ES = spec.N, spec.ES
                scale = np.maximum(np.abs(arr).max(axis=tuple(range(arr.ndim - 1)),
                                                   keepdims=True), 1e-12)
                scale = np.exp2(np.ceil(np.log2(scale))).astype(np.float32)
                codes = norm_encode_np((arr / scale).astype(np.float64), N, ES)
                packed = pack_bits(codes, N - 1)
                np.save(os.path.join(tmp, name), packed)
                np.save(os.path.join(tmp, f"scale_{i:05d}.npy"), scale)
                entry.update(N=N, ES=ES, count=int(arr.size),
                             scale_file=f"scale_{i:05d}.npy")
            else:
                np.save(os.path.join(tmp, name), arr)
            manifest["leaves"].append(entry)
        with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
            pickle.dump(treedef, f)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore ----------------------------------------------------------------

    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, "manifest.json")):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, shardings: Any = None) -> Any:
        """Load a checkpoint; device_put onto ``shardings`` (elastic restore).

        shardings: optional pytree (same treedef) of NamedSharding/None.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        root = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(root, "manifest.json")) as f:
            manifest = json.load(f)
        with open(os.path.join(root, "treedef.pkl"), "rb") as f:
            treedef = pickle.load(f)
        leaves = []
        for entry in manifest["leaves"]:
            raw = np.load(os.path.join(root, entry["file"]))
            if entry.get("compressed"):
                N, ES = entry["N"], entry["ES"]
                codes = unpack_bits(raw, N - 1, entry["count"])
                scale = np.load(os.path.join(root, entry["scale_file"]))
                arr = (norm_decode_np(codes, N, ES).reshape(entry["shape"])
                       * scale).astype(_np_dtype(entry["dtype"]))
            else:
                arr = _reinterpret(raw, entry["dtype"])
            leaves.append(arr)
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            flat_s, treedef_s = jax.tree_util.tree_flatten(
                shardings, is_leaf=lambda x: x is None)
            flat_x = treedef_s.flatten_up_to(state)
            state = treedef_s.unflatten([
                jax.device_put(x, s) if s is not None else jnp.asarray(x)
                for x, s in zip(flat_x, flat_s)])
        else:
            state = jax.tree.map(jnp.asarray, state)
        return state

"""Model facade: one ``LM`` object per (ModelConfig, RunConfig, mesh).

Provides, for every assigned family (dense / moe / encdec / ssm / hybrid):

  init(key)            parameters (layer-stacked pytree, fp32 or bf16)
  logical()            logical-axis tree (same treedef) for sharding
  abstract_params()    ShapeDtypeStruct tree via eval_shape (dry-run: no alloc)
  forward()            full-sequence logits (train / prefill)
  loss()               vocab-parallel cross-entropy (+ MoE aux loss)
  train_step()         grad accumulation + clip + AdamW (see repro.optim)
  init_cache()         decode state (KV / SSM), sequence- or batch-sharded
  prefill()/decode_step()  serving path; cache donated by the launcher

The paper's technique enters through ``apply_policy`` (uniform back-compat
shim: ``quantize_params``): eligible matmul weights become
``QuantizedTensor`` (normalized-posit codes + normalizer scale) in the
format the QuantPolicy's path rules assign them; every layer dispatches
through ``matmul_param`` which routes quantized weights to the PoFx
datapath, so mixed per-layer formats coexist in one forward pass. Norms /
SSM recurrence params / router weights are excluded (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.core.policy import QuantPolicy
from repro.core.quantizers import (QuantSpec, QuantizedTensor, kv_code_dtype,
                                   quantize, validate_kv_spec)
from .layers import dense_init, matmul_param, param_value, rmsnorm
from .sharding import ShardingCtx, make_ctx
from . import transformer as T
from . import ssm as S

__all__ = ["LM", "build_model", "apply_policy", "quantize_params",
           "input_specs", "ce_loss", "kv_decode_bytes_per_token"]


def _dt(name: str):
    return {"f32": jnp.float32, "fp32": jnp.float32, "bf16": jnp.bfloat16}[name]


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def ce_loss(logits: jax.Array, labels: jax.Array, *, z_weight: float = 0.0):
    """Vocab-parallel cross-entropy. logits (B,S,V) may be vocab-sharded;
    every reduction is over the V axis so GSPMD lowers to per-shard partials
    + a small all-reduce (no logits all-gather)."""
    lg = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lg, axis=-1, keepdims=True))
    shifted = lg - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, lg.shape[-1], dtype=lg.dtype)
    ll = jnp.sum(lg * onehot, axis=-1)
    nll = jnp.mean(lse - ll)
    if z_weight:
        nll = nll + z_weight * jnp.mean(jnp.square(lse))
    return nll


# ---------------------------------------------------------------------------
# LM facade
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LM:
    cfg: ModelConfig
    rcfg: RunConfig
    ctx: ShardingCtx
    use_kernel: bool = False
    # Decode KV-cache format (DESIGN.md §8): a byte-wide fxp/pofx QuantSpec
    # makes init_cache allocate code+scale leaves and routes decode through
    # the quantized datapath; None keeps the bf16/f32 cache. kv_kernel
    # selects the fused Pallas flash-decode kernel (None: follow
    # use_kernel) vs the XLA quantize-on-write/dequantize-on-read fallback.
    kv_spec: Optional[QuantSpec] = None
    kv_kernel: Optional[bool] = None

    def __post_init__(self):
        self.kv_spec = validate_kv_spec(self.kv_spec)

    @property
    def kv_use_kernel(self) -> bool:
        return self.use_kernel if self.kv_kernel is None else self.kv_kernel

    # -- construction helpers ------------------------------------------------

    @property
    def act_dtype(self):
        return _dt(getattr(self.rcfg, "activation_dtype", "bf16"))

    @property
    def param_dtype(self):
        return _dt(self.rcfg.weight_dtype)

    @property
    def n_groups(self) -> int:
        cfg = self.cfg
        if cfg.family == "moe":
            return cfg.n_layers // cfg.moe_every
        return cfg.n_layers

    def _hybrid_chunks(self):
        """zamba2: layer-count chunks between shared-block applications."""
        cfg = self.cfg
        k, L = cfg.attn_every, cfg.n_layers
        sizes = []
        done = 0
        while done < L:
            sizes.append(min(k, L - done))
            done += sizes[-1]
        return sizes

    # -- init / logical -------------------------------------------------------

    def init(self, key) -> Dict[str, Any]:
        cfg, dt = self.cfg, self.param_dtype
        ks = jax.random.split(key, 8)
        V, d = cfg.padded_vocab, cfg.d_model
        params: Dict[str, Any] = {
            "embed": dense_init(ks[0], V, d, scale=1.0, dtype=dt),
            "ln_f": jnp.ones((d,), dt),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = dense_init(ks[1], d, V, dtype=dt)
        fam = cfg.family
        if fam == "dense":
            params["blocks"] = T.stack_init(T.dense_block_init, ks[2], cfg.n_layers, cfg, dt)
        elif fam == "moe":
            ng = self.n_groups
            params["blocks"] = {"moe": T.stack_init(T.moe_block_init, ks[2], ng, cfg, dt)}
            if cfg.moe_every > 1:
                def group_dense(k, cfg=cfg, dt=dt):
                    kk = jax.random.split(k, cfg.moe_every - 1)
                    return jax.vmap(lambda q: T.dense_block_init(q, cfg, dt))(kk)
                params["blocks"]["dense"] = T.stack_init(group_dense, ks[3], ng)
        elif fam == "encdec":
            params["enc_blocks"] = T.stack_init(
                T.encdec_block_init, ks[2], cfg.n_enc_layers, cfg, dt)
            params["enc_ln"] = jnp.ones((d,), dt)
            params["blocks"] = T.stack_init(
                functools.partial(T.encdec_block_init, cross=True),
                ks[3], cfg.n_layers, cfg, dt)
        elif fam == "ssm":
            params["blocks"] = T.stack_init(T.mamba_block_init, ks[2], cfg.n_layers, cfg, dt)
        elif fam == "hybrid":
            params["blocks"] = T.stack_init(T.mamba_block_init, ks[2], cfg.n_layers, cfg, dt)
            params["shared"] = T.dense_block_init(ks[3], cfg, dt)
        else:
            raise ValueError(f"unknown family {fam!r}")
        return params

    def logical(self) -> Dict[str, Any]:
        cfg = self.cfg
        # Under posit8 gradient compression the step runs inside a
        # shard_map whose "pod" axis is manual; XLA's PartitionGather
        # CHECK-fails on a gather from a vocab-sharded table in that mode,
        # so the embed table keeps its vocab dim replicated there (the
        # d_model dim still FSDP-shards; unembed stays vocab-parallel —
        # matmuls partition fine).
        compressed = str(self.rcfg.grad_compression).startswith("posit8")
        out: Dict[str, Any] = {
            "embed": (None if compressed else "vocab", "p_embed"),
            "ln_f": ("p_unsharded",),
        }
        if not cfg.tie_embeddings:
            out["unembed"] = ("p_embed", "vocab")
        fam = cfg.family
        if fam == "dense":
            out["blocks"] = T.stack_logical(T.dense_block_logical(cfg))
        elif fam == "moe":
            out["blocks"] = {"moe": T.stack_logical(T.moe_block_logical(cfg))}
            if cfg.moe_every > 1:
                out["blocks"]["dense"] = T.stack_logical(
                    T.stack_logical(T.dense_block_logical(cfg)))
        elif fam == "encdec":
            out["enc_blocks"] = T.stack_logical(T.encdec_block_logical(cfg))
            out["enc_ln"] = ("p_unsharded",)
            out["blocks"] = T.stack_logical(T.encdec_block_logical(cfg, cross=True))
        elif fam == "ssm":
            out["blocks"] = T.stack_logical(T.mamba_block_logical(cfg))
        elif fam == "hybrid":
            out["blocks"] = T.stack_logical(T.mamba_block_logical(cfg))
            out["shared"] = T.dense_block_logical(cfg)
        return out

    def abstract_params(self):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def param_shardings(self, params_shape=None):
        """NamedSharding tree matching abstract/concrete params."""
        params_shape = params_shape or self.abstract_params()
        logical = self.logical()
        return jax.tree.map(
            lambda leaf, ax: self.ctx.sharding(ax, leaf.shape),
            params_shape, logical,
            is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))

    # -- forward (train / prefill) -------------------------------------------

    def forward(self, params, tokens, *, frames=None) -> jax.Array:
        cfg, rcfg, ctx = self.cfg, self.rcfg, self.ctx
        B, Sq = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(Sq)[None, :], (B, Sq))
        x = T.embed_tokens(params["embed"], tokens, ctx, self.act_dtype)
        fam = cfg.family
        if fam == "dense":
            def body(h, lp, _):
                y, _ = T.dense_block_forward(lp, h, cfg, ctx, rcfg,
                                             positions=positions,
                                             use_kernel=self.use_kernel)
                return y, None
            x, _ = T.scan_blocks(body, x, params["blocks"], rcfg, length=cfg.n_layers)
        elif fam == "moe":
            def body(h, lp, _):
                if "dense" in params["blocks"]:
                    for i in range(cfg.moe_every - 1):
                        dlp = jax.tree.map(lambda a: a[i], lp["dense"])
                        h, _ = T.dense_block_forward(dlp, h, cfg, ctx, rcfg,
                                                     positions=positions,
                                                     use_kernel=self.use_kernel)
                h, _ = T.moe_block_forward(lp["moe"], h, cfg, ctx, rcfg,
                                           positions=positions,
                                           use_kernel=self.use_kernel)
                return h, None
            x, _ = T.scan_blocks(body, x, params["blocks"], rcfg, length=self.n_groups)
        elif fam == "encdec":
            assert frames is not None, "encdec forward needs encoder frames"
            xa = self._encode(params, frames)
            def body(h, lp, _):
                y, _ = T.decoder_xblock_forward(lp, h, cfg, ctx, rcfg,
                                                positions=positions, xa=xa,
                                                use_kernel=self.use_kernel)
                return y, None
            x, _ = T.scan_blocks(body, x, params["blocks"], rcfg, length=cfg.n_layers)
        elif fam == "ssm":
            def body(h, lp, _):
                y, _ = T.mamba_block_forward(lp, h, cfg, ctx, variant="mamba1",
                                             use_kernel=self.use_kernel)
                return y, None
            x, _ = T.scan_blocks(body, x, params["blocks"], rcfg, length=cfg.n_layers)
        elif fam == "hybrid":
            x = self._hybrid_forward(params, x, positions)
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        w_un = params["embed"] if cfg.tie_embeddings else params["unembed"]
        if cfg.tie_embeddings:
            logits = matmul_param(x, jnp.swapaxes(param_value(w_un, x.dtype), 0, 1))
            return ctx.constrain(logits, "batch", "seq_attn", "vocab")
        return T.unembed(x, w_un, ctx, use_kernel=self.use_kernel)

    def _encode(self, params, frames):
        cfg, rcfg, ctx = self.cfg, self.rcfg, self.ctx
        B, Se, _ = frames.shape
        pos = jnp.broadcast_to(jnp.arange(Se)[None, :], (B, Se))
        xa = frames.astype(self.act_dtype)
        xa = ctx.constrain(xa, "batch", "seq", None)
        def body(h, lp, _):
            y, _ = T.dense_block_forward(lp, h, cfg, ctx, rcfg, positions=pos,
                                         causal=False, use_kernel=self.use_kernel)
            return y, None
        xa, _ = T.scan_blocks(body, xa, params["enc_blocks"], rcfg,
                              length=cfg.n_enc_layers)
        return rmsnorm(xa, params["enc_ln"], cfg.norm_eps)

    def _hybrid_forward(self, params, x, positions):
        cfg, rcfg, ctx = self.cfg, self.rcfg, self.ctx
        chunks = self._hybrid_chunks()
        off = 0
        shared_fwd = T.dense_block_forward
        if rcfg.remat == "block":
            shared_fwd = jax.checkpoint(shared_fwd, static_argnums=(2, 3, 4))
        for size in chunks:
            x, _ = shared_fwd(params["shared"], x, cfg, ctx, rcfg,
                              positions=positions, use_kernel=self.use_kernel)
            sub = jax.tree.map(lambda a: a[off:off + size], params["blocks"])
            def body(h, lp, _):
                y, _ = T.mamba_block_forward(lp, h, cfg, ctx, variant="mamba2",
                                             use_kernel=self.use_kernel)
                return y, None
            x, _ = T.scan_blocks(body, x, sub, rcfg, length=size)
            off += size
        return x

    # -- loss ------------------------------------------------------------------

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        logits = self.forward(params, batch["tokens"], frames=batch.get("frames"))
        nll = ce_loss(logits, batch["labels"])
        return nll, {"loss": nll}

    # -- decode ----------------------------------------------------------------

    def _kv_cache(self, batch: int, max_len: int,
                  kv_spec: Optional[QuantSpec] = None):
        # heads-major (B, G, S, Dh): decode einsums contract on the minor
        # axis with (b, g) batch dims — no per-step cache transpose.
        # Quantized caches (DESIGN.md §8) hold byte-wide codes next to a
        # STATIC per-head-dim-channel scale leaf (B, G, 1, Dh); static so
        # quantize-on-write is deterministic and evict -> re-prefill resume
        # stays bit-identical.
        cfg = self.cfg
        G, Dh = cfg.n_kv_heads, cfg.d_head
        if kv_spec is not None:
            cdt = kv_code_dtype(kv_spec)
            return {"k": jnp.zeros((batch, G, max_len, Dh), cdt),
                    "k_scale": jnp.ones((batch, G, 1, Dh), jnp.float32),
                    "v": jnp.zeros((batch, G, max_len, Dh), cdt),
                    "v_scale": jnp.ones((batch, G, 1, Dh), jnp.float32)}
        kdt = _dt(self.rcfg.kv_cache_dtype) if self.rcfg.kv_cache_dtype != "int8" else jnp.bfloat16
        return {"k": jnp.zeros((batch, G, max_len, Dh), kdt),
                "v": jnp.zeros((batch, G, max_len, Dh), kdt)}

    def init_cache(self, batch: int, max_len: int,
                   enc_len: Optional[int] = None,
                   kv_spec="auto") -> Dict[str, Any]:
        """Zero decode cache (stacked over layers/groups).

        enc_len sizes the encdec cross-attention cache (defaults to
        max_len). kv_spec overrides the model's KV-cache format ("auto":
        use ``self.kv_spec``); a quantized spec allocates code+scale
        leaves instead of float K/V (DESIGN.md §8). The override is
        allocation-only (sizing / eval_shape): prefill and decode_step
        reject a cache whose layout disagrees with the model's own
        kv_spec rather than silently casting floats into code leaves.
        """
        cfg = self.cfg
        fam = cfg.family
        spec = self.kv_spec if kv_spec == "auto" else validate_kv_spec(kv_spec)
        if spec is not None and fam == "encdec":
            raise ValueError(
                "quantized KV cache is not supported for encdec: the "
                "legacy one-shot path owns its cross-attention cache "
                "(DESIGN.md §8)")
        def stack(make, n):
            return jax.tree.map(lambda *xs: jnp.stack(xs), *[make() for _ in range(n)])
        mk = lambda: self._kv_cache(batch, max_len, spec)
        cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
        if fam == "dense":
            cache["kv"] = stack(mk, cfg.n_layers)
        elif fam == "moe":
            ng = self.n_groups
            cache["kv"] = {"moe": stack(mk, ng)}
            if cfg.moe_every > 1:
                cache["kv"]["dense"] = stack(
                    lambda: stack(mk, cfg.moe_every - 1), ng)
        elif fam == "encdec":
            cache["kv"] = stack(mk, cfg.n_layers)
            cache["cross"] = stack(lambda: self._kv_cache(batch, enc_len or max_len),
                                   cfg.n_layers)
            cache["xlen"] = jnp.zeros((), jnp.int32)
        elif fam == "ssm":
            cache["ssm"] = stack(lambda: S.mamba1_init_cache(cfg, batch), cfg.n_layers)
        elif fam == "hybrid":
            cache["ssm"] = stack(lambda: S.mamba2_init_cache(cfg, batch), cfg.n_layers)
            cache["shared_kv"] = stack(mk, len(self._hybrid_chunks()))
        return cache

    def cache_logical(self) -> Dict[str, Any]:
        """Logical axes for every cache leaf (seq-sharded KV for decode).

        Quantized caches add per-head-dim-channel scale leaves; codes keep
        the float leaves' kv_seq sharding (the flash-decode combine over a
        sequence-sharded cache works on codes exactly as on floats). The
        head axis is named "kv_heads_c": unmapped on production meshes
        (kv_seq sharding wins there) but sharded by the serving TP mesh
        (DESIGN.md §9), where codes AND their static scales split along the
        same head axis as the attention weights.
        """
        cfg = self.cfg
        fam = cfg.family
        kv = {"k": ("layers", "batch", "kv_heads_c", "kv_seq", "head_dim"),
              "v": ("layers", "batch", "kv_heads_c", "kv_seq", "head_dim")}
        if self.kv_spec is not None:
            kv["k_scale"] = ("layers", "batch", "kv_heads_c", None, "head_dim")
            kv["v_scale"] = ("layers", "batch", "kv_heads_c", None, "head_dim")
        out: Dict[str, Any] = {"pos": ()}
        if fam == "dense":
            out["kv"] = kv
        elif fam == "moe":
            out["kv"] = {"moe": kv}
            if cfg.moe_every > 1:
                dense_kv = {
                    "k": ("layers", "layers2", "batch", "kv_heads_c", "kv_seq", "head_dim"),
                    "v": ("layers", "layers2", "batch", "kv_heads_c", "kv_seq", "head_dim")}
                if self.kv_spec is not None:
                    dense_kv["k_scale"] = ("layers", "layers2", "batch",
                                           "kv_heads_c", None, "head_dim")
                    dense_kv["v_scale"] = ("layers", "layers2", "batch",
                                           "kv_heads_c", None, "head_dim")
                out["kv"]["dense"] = dense_kv
        elif fam == "encdec":
            out["kv"] = {"k": kv["k"], "v": kv["v"]}
            out["cross"] = {"k": kv["k"], "v": kv["v"]}
            out["xlen"] = ()
        elif fam == "ssm":
            out["ssm"] = {"conv": ("layers", "batch", "conv", "d_inner"),
                          "ssm": ("layers", "batch", "d_inner", "state")}
        elif fam == "hybrid":
            out["ssm"] = {"conv": ("layers", "batch", "conv", "d_inner2"),
                          "ssm": ("layers", "batch", "heads_r", None, "state")}
            out["shared_kv"] = kv
        return out

    # -- paged decode cache (DESIGN.md §10) -----------------------------------

    def _paged_pool(self, n_pages: int, page_size: int,
                    spec: Optional[QuantSpec]):
        # One layer's page pool: a flat (n_pages, G, ps, Dh) array of
        # fixed-size token pages (heads-major within the page, so the
        # decode einsums see the dense cache's layout after gather) plus —
        # quantized — ONE static per-channel scale leaf per layer, global
        # across pages: pages are shareable between requests only because
        # every page quantizes under the same grid (paging.py).
        cfg = self.cfg
        G, Dh = cfg.n_kv_heads, cfg.d_head
        if spec is not None:
            cdt = kv_code_dtype(spec)
            return {"k": jnp.zeros((n_pages, G, page_size, Dh), cdt),
                    "k_scale": jnp.ones((G, 1, Dh), jnp.float32),
                    "v": jnp.zeros((n_pages, G, page_size, Dh), cdt),
                    "v_scale": jnp.ones((G, 1, Dh), jnp.float32)}
        kdt = _dt(self.rcfg.kv_cache_dtype) \
            if self.rcfg.kv_cache_dtype != "int8" else jnp.bfloat16
        return {"k": jnp.zeros((n_pages, G, page_size, Dh), kdt),
                "v": jnp.zeros((n_pages, G, page_size, Dh), kdt)}

    def _require_pageable(self) -> None:
        if self.cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"paged KV cache supports attention-only families "
                f"(dense/moe), not {self.cfg.family!r}: SSM recurrent "
                "state is O(1) per sequence (nothing to page) and cannot "
                "be position-shared, and encdec serves on the legacy "
                "one-shot path (DESIGN.md §10)")

    def init_paged_cache(self, batch: int, max_len: int, *, n_pages: int,
                         page_size: int) -> Dict[str, Any]:
        """Paged decode cache: page pools + per-slot block tables.

        Layout mirrors ``init_cache`` except the batch*seq cache axes are
        replaced by one flat ``n_pages`` pool axis shared by every slot;
        ``pages`` is the (batch, ceil(max_len/page_size)) block table of
        physical page ids (garbage-page 0 when unallocated) the host-side
        ``launch.paging.PagedKVManager`` maintains, and ``pos`` is the
        dense engine's per-slot length vector unchanged.
        """
        self._require_pageable()
        cfg = self.cfg
        ps = int(page_size)
        if ps < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        max_pages = -(-int(max_len) // ps)
        spec = self.kv_spec

        def stack(make, n):
            return jax.tree.map(lambda *xs: jnp.stack(xs),
                                *[make() for _ in range(n)])
        mk = lambda: self._paged_pool(n_pages, ps, spec)
        cache: Dict[str, Any] = {
            "pos": jnp.zeros((batch,), jnp.int32),
            "pages": jnp.zeros((batch, max_pages), jnp.int32),
        }
        if cfg.family == "dense":
            cache["kv"] = stack(mk, cfg.n_layers)
        else:                                   # moe
            ng = self.n_groups
            cache["kv"] = {"moe": stack(mk, ng)}
            if cfg.moe_every > 1:
                cache["kv"]["dense"] = stack(
                    lambda: stack(mk, cfg.moe_every - 1), ng)
        return cache

    def paged_cache_logical(self) -> Dict[str, Any]:
        """Logical axes for the paged cache: the pool's head axis keeps the
        dense cache's "kv_heads_c" name, so serving-TP sharding (DESIGN.md
        §9) splits pages and their global scales along heads exactly as it
        splits the dense cache; pool/page axes and the block tables
        replicate (every device resolves the same page ids)."""
        self._require_pageable()
        cfg = self.cfg
        kv = {"k": ("layers", "kv_pages", "kv_heads_c", "page_tok",
                    "head_dim"),
              "v": ("layers", "kv_pages", "kv_heads_c", "page_tok",
                    "head_dim")}
        if self.kv_spec is not None:
            kv["k_scale"] = ("layers", "kv_heads_c", None, "head_dim")
            kv["v_scale"] = ("layers", "kv_heads_c", None, "head_dim")
        out: Dict[str, Any] = {"pos": (), "pages": ()}
        if cfg.family == "dense":
            out["kv"] = kv
        else:
            out["kv"] = {"moe": kv}
            if cfg.moe_every > 1:
                out["kv"]["dense"] = {
                    name: (ax[0], "layers2") + ax[1:]
                    for name, ax in kv.items()}
        return out

    def _paged_page_size(self, cache) -> int:
        kv = cache["kv"]["moe"] if "moe" in cache["kv"] else cache["kv"]
        return int(kv["k"].shape[-2])

    def prefill_paged(self, params, tokens, *, cache, slot, length,
                      prefix_len: int = 0):
        """Admission prefill through the page pool (batch 1, one slot).

        ``tokens`` (1, S) is the context *suffix* — the part not already
        resident in shared pages — right-padded to a bucket when S exceeds
        the true ``length`` (scalar, traced). ``prefix_len`` (static: it
        sets gather sizes and the attention bias offset) counts the shared
        resident tokens; the suffix attends to [prefix ; suffix] with the
        kv-chunk boundaries a dense prefill of the whole context would
        use, so the sampled logits match the dense engine's, and writes
        its codes through slot's block-table row. Sets ``pos[slot]`` to
        ``prefix_len + length``. Returns (cache, last-token logits).
        """
        self._require_pageable()
        cfg, rcfg, ctx = self.cfg, self.rcfg, self.ctx
        self._check_cache_layout(cache)
        B, Sq = tokens.shape
        positions = prefix_len + jnp.broadcast_to(jnp.arange(Sq)[None, :],
                                                  (B, Sq))
        x = T.embed_tokens(params["embed"], tokens, ctx, self.act_dtype)
        row = jnp.take(cache["pages"], slot, axis=0)
        ps = self._paged_page_size(cache)
        kv_spec = self.kv_spec
        pp = lambda lc: dict(pool=lc, row=row, prefix_len=prefix_len,
                             page_size=ps)
        if cfg.family == "dense":
            def body(h, lp, lc):
                return T.dense_block_forward(lp, h, cfg, ctx, rcfg,
                                             positions=positions,
                                             use_kernel=self.use_kernel,
                                             kv_spec=kv_spec,
                                             paged_prefill=pp(lc))
            x, new_kv = T.scan_blocks(body, x, params["blocks"], rcfg,
                                      cache=cache["kv"],
                                      length=cfg.n_layers)
        else:                                   # moe
            def body(h, lp, lc):
                new_c = dict(lc)
                if "dense" in params["blocks"]:
                    pools = []
                    for i in range(cfg.moe_every - 1):
                        dlp = jax.tree.map(lambda a: a[i], lp["dense"])
                        dlc = jax.tree.map(lambda a: a[i], lc["dense"])
                        h, pool = T.dense_block_forward(
                            dlp, h, cfg, ctx, rcfg, positions=positions,
                            use_kernel=self.use_kernel, kv_spec=kv_spec,
                            paged_prefill=pp(dlc))
                        pools.append(pool)
                    new_c["dense"] = jax.tree.map(
                        lambda *xs: jnp.stack(xs), *pools)
                h, pool = T.moe_block_forward(lp["moe"], h, cfg, ctx, rcfg,
                                              positions=positions,
                                              use_kernel=self.use_kernel,
                                              kv_spec=kv_spec,
                                              paged_prefill=pp(lc["moe"]))
                new_c["moe"] = pool
                return h, new_c
            blocks_cache = {"moe": cache["kv"]["moe"]}
            if "dense" in cache["kv"]:
                blocks_cache["dense"] = cache["kv"]["dense"]
            x, new_kv = T.scan_blocks(body, x, params["blocks"], rcfg,
                                      cache=blocks_cache,
                                      length=self.n_groups)
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        length = jnp.asarray(length, jnp.int32)
        last = jnp.take_along_axis(
            x, jnp.reshape(length - 1, (B, 1, 1)), axis=1)[:, 0]
        w_un = params["embed"] if cfg.tie_embeddings else params["unembed"]
        if cfg.tie_embeddings:
            logits = matmul_param(
                last, jnp.swapaxes(param_value(w_un, x.dtype), 0, 1))
        else:
            logits = matmul_param(last, w_un, use_kernel=self.use_kernel)
        cache = dict(cache, kv=new_kv,
                     pos=cache["pos"].at[slot].set(prefix_len + length))
        return cache, logits

    def _check_cache_layout(self, cache) -> None:
        # A cache allocated under a different kv_spec than the model's
        # (init_cache(kv_spec=...) is an allocation override only) would
        # silently astype float K/V into int8 code leaves — or attend to
        # raw codes as if they were floats. Runs at trace time.
        kv = cache.get("kv") if "kv" in cache else cache.get("shared_kv")
        if not isinstance(kv, dict):
            return
        if "k" not in kv:                     # moe nests {"moe": ..., ...}
            kv = kv.get("moe", {})
            if "k" not in kv:
                return
        quant = "k_scale" in kv
        if (self.kv_spec is not None) != quant:
            raise ValueError(
                f"cache layout disagrees with the model's kv_spec="
                f"{self.kv_spec!r}: the cache "
                f"{'has' if quant else 'lacks'} scale leaves (was it "
                "allocated by init_cache(kv_spec=...) with a different "
                "format?)")
        if quant and kv["k"].dtype != kv_code_dtype(self.kv_spec):
            raise ValueError(
                f"cache code dtype {kv['k'].dtype} does not match the "
                f"model's kv_spec={self.kv_spec!r} "
                f"(expects {jnp.dtype(kv_code_dtype(self.kv_spec)).name})")

    def cache_shardings(self, batch: int, max_len: int):
        abstract = jax.eval_shape(lambda: self.init_cache(batch, max_len))
        logical = self.cache_logical()
        return jax.tree.map(
            lambda leaf, ax: self.ctx.sharding(ax, leaf.shape),
            abstract, logical,
            is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))

    # -- serving tensor parallelism (DESIGN.md §9) ----------------------------

    @property
    def tp_size(self) -> int:
        """Devices on the serving TP mesh (1 = single-device serving)."""
        return self.ctx.axis_size("tp")

    def manual_tp(self) -> "LM":
        """Twin of this model for use INSIDE a shard_map over the TP mesh:
        constrain no-ops, ``ctx.psum`` is live, and every weight/cache leaf
        the twin sees is the local shard."""
        from .sharding import manual_tp_ctx
        return dataclasses.replace(self, ctx=manual_tp_ctx())

    def param_tp_specs(self, params):
        """PartitionSpec tree (QuantizedTensor-shaped at quantized leaves)
        for the serving TP mesh; raises on indivisible/incongruent leaves."""
        from .sharding import shard_policy_params
        return shard_policy_params(params, self.logical(), self.ctx)

    def cache_tp_specs(self, cache):
        """PartitionSpec tree for a decode cache on the serving TP mesh
        (head-sharded codes AND scales; everything else replicated).
        Detects the paged layout by its block-table leaf."""
        from .sharding import logical_specs
        logical = (self.paged_cache_logical() if "pages" in cache
                   else self.cache_logical())
        return logical_specs(self.ctx, logical, cache)

    def prefill(self, params, tokens, *, cache, frames=None, length=None):
        """Run the full prompt, filling the cache. Returns (cache, last_logits).

        Implemented as forward + cache writes per layer; decode-shape dry-run
        only lowers decode_step, so prefill stays straightforward (chunked
        attention still applies).

        ``length`` (scalar or (B,) int) marks the true prompt length when
        ``tokens`` is right-padded to a bucket (the serving engine pads to
        limit prefill recompilation): logits are gathered at ``length-1``
        and ``cache["pos"]`` becomes the per-sequence length, so the junk
        KV written for pad positions sits beyond every slot's valid prefix
        and is masked (then progressively overwritten) during decode.
        Attention-family models only — a right-padded prompt would pollute
        an SSM recurrent state, which has no per-position mask.
        """
        cfg, rcfg, ctx = self.cfg, self.rcfg, self.ctx
        self._check_cache_layout(cache)
        B, Sq = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(Sq)[None, :], (B, Sq))
        x = T.embed_tokens(params["embed"], tokens, ctx, self.act_dtype)
        fam = cfg.family
        max_len = _cache_len(cache)
        kv_spec = self.kv_spec

        def scales_of(layer_cache):
            # quantized cache: hand the layer's static scales to the block
            # so prefill fake-quantizes K/V through the cache grid before
            # attending (bit-identical evict -> re-prefill resume).
            if kv_spec is None or "k_scale" not in layer_cache:
                return None
            return {"k_scale": layer_cache["k_scale"],
                    "v_scale": layer_cache["v_scale"]}

        def write_kv(layer_cache, new_kv):
            # grouped (B, S, G, Dh) -> heads-major cache (B, G, S, Dh);
            # quantized caches receive codes (same layout, code dtype) and
            # keep their scale leaves untouched.
            out = dict(layer_cache)
            for name in ("k", "v"):
                dst = layer_cache[name]
                upd = jnp.swapaxes(new_kv[name], 1, 2).astype(dst.dtype)
                out[name] = jax.lax.dynamic_update_slice_in_dim(
                    dst, upd, 0, axis=2)
            return out

        if fam == "dense":
            def body(h, lp, lc):
                y, kv = T.dense_block_forward(lp, h, cfg, ctx, rcfg,
                                              positions=positions,
                                              use_kernel=self.use_kernel,
                                              kv_spec=kv_spec,
                                              kv_scales=scales_of(lc))
                return y, write_kv(lc, kv)
            x, new_kv = T.scan_blocks(body, x, params["blocks"], rcfg,
                                      cache=cache["kv"], length=cfg.n_layers)
            cache = dict(cache, kv=new_kv)
        elif fam == "moe":
            def body(h, lp, lc):
                new_c = dict(lc)
                if "dense" in params["blocks"]:
                    writes = []
                    for i in range(cfg.moe_every - 1):
                        dlp = jax.tree.map(lambda a: a[i], lp["dense"])
                        dlc = jax.tree.map(lambda a: a[i], lc["dense"])
                        h, kv = T.dense_block_forward(dlp, h, cfg, ctx, rcfg,
                                                      positions=positions,
                                                      use_kernel=self.use_kernel,
                                                      kv_spec=kv_spec,
                                                      kv_scales=scales_of(dlc))
                        writes.append(write_kv(dlc, kv))
                    new_c["dense"] = jax.tree.map(
                        lambda *xs: jnp.stack(xs), *writes)
                h, kv = T.moe_block_forward(lp["moe"], h, cfg, ctx, rcfg,
                                            positions=positions,
                                            use_kernel=self.use_kernel,
                                            kv_spec=kv_spec,
                                            kv_scales=scales_of(lc["moe"]))
                new_c["moe"] = write_kv(lc["moe"], kv)
                return h, new_c
            blocks_cache = {"moe": cache["kv"]["moe"]}
            if "dense" in cache["kv"]:
                blocks_cache["dense"] = cache["kv"]["dense"]
            x, new_kv = T.scan_blocks(body, x, params["blocks"], rcfg,
                                      cache=blocks_cache, length=self.n_groups)
            cache = dict(cache, kv=new_kv)
        elif fam == "encdec":
            assert frames is not None
            xa = self._encode(params, frames)
            def body(h, lp, lc):
                y, kv = T.decoder_xblock_forward(lp, h, cfg, ctx, rcfg,
                                                 positions=positions, xa=xa,
                                                 use_kernel=self.use_kernel)
                # also record cross-attn k/v once (static thereafter)
                from .attention import attn_tp_mode
                G, Dh = cfg.n_kv_heads, cfg.d_head
                xk = matmul_param(xa, lp["xattn"]["wk"]).reshape(xa.shape[0], -1, G, Dh)
                xv = matmul_param(xa, lp["xattn"]["wv"]).reshape(xa.shape[0], -1, G, Dh)
                new_c = {"self": write_kv(lc["self"], kv),
                         "cross": write_kv(lc["cross"], {"k": xk, "v": xv})}
                return y, new_c
            x, new_c = T.scan_blocks(body, x, params["blocks"], rcfg,
                                     cache={"self": cache["kv"], "cross": cache["cross"]},
                                     length=cfg.n_layers)
            cache = dict(cache, kv=new_c["self"], cross=new_c["cross"],
                         xlen=jnp.asarray(frames.shape[1], jnp.int32))
        elif fam == "ssm":
            def body(h, lp, lc):
                y, nc = T.mamba_block_forward(lp, h, cfg, ctx, cache=lc,
                                              variant="mamba1",
                                              use_kernel=self.use_kernel)
                return y, nc
            x, new_ssm = T.scan_blocks(body, x, params["blocks"], rcfg,
                                       cache=cache["ssm"], length=cfg.n_layers)
            cache = dict(cache, ssm=new_ssm)
        elif fam == "hybrid":
            x, cache = self._hybrid_prefill(params, x, positions, cache, write_kv)
        x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
        w_un = params["embed"] if cfg.tie_embeddings else params["unembed"]
        if length is None:
            last = x[:, -1]
            pos = jnp.asarray(tokens.shape[1], jnp.int32)
        else:
            if cfg.family in ("ssm", "hybrid"):
                raise ValueError(
                    "bucketed prefill (length=) is attention-family only: "
                    "right-padding pollutes the SSM recurrent state")
            pos = jnp.broadcast_to(jnp.asarray(length, jnp.int32), (B,))
            last = jnp.take_along_axis(x, (pos - 1)[:, None, None], axis=1)[:, 0]
        if cfg.tie_embeddings:
            logits = matmul_param(last, jnp.swapaxes(param_value(w_un, x.dtype), 0, 1))
        else:
            logits = matmul_param(last, w_un, use_kernel=self.use_kernel)
        cache["pos"] = pos
        return cache, logits

    def _hybrid_prefill(self, params, x, positions, cache, write_kv):
        cfg, rcfg, ctx = self.cfg, self.rcfg, self.ctx
        chunks = self._hybrid_chunks()
        off = 0
        shared_new = []
        ssm_new = []
        for ci, size in enumerate(chunks):
            lc = jax.tree.map(lambda a: a[ci], cache["shared_kv"])
            kv_scales = None
            if self.kv_spec is not None and "k_scale" in lc:
                kv_scales = {"k_scale": lc["k_scale"],
                             "v_scale": lc["v_scale"]}
            x, kv = T.dense_block_forward(params["shared"], x, cfg, ctx, rcfg,
                                          positions=positions,
                                          use_kernel=self.use_kernel,
                                          kv_spec=self.kv_spec,
                                          kv_scales=kv_scales)
            shared_new.append(write_kv(lc, kv))
            sub = jax.tree.map(lambda a: a[off:off + size], params["blocks"])
            subc = jax.tree.map(lambda a: a[off:off + size], cache["ssm"])
            def body(h, lp, lcc):
                y, nc = T.mamba_block_forward(lp, h, cfg, ctx, cache=lcc,
                                              variant="mamba2",
                                              use_kernel=self.use_kernel)
                return y, nc
            x, new_sub = T.scan_blocks(body, x, sub, rcfg, cache=subc, length=size)
            ssm_new.append(new_sub)
            off += size
        cache = dict(cache)
        cache["shared_kv"] = jax.tree.map(lambda *xs: jnp.stack(xs), *shared_new)
        cache["ssm"] = jax.tree.map(lambda *xs: jnp.concatenate(xs), *ssm_new)
        return x, cache

    def decode_step(self, params, cache, tokens):
        """One decode step. tokens: (B, 1). Returns (new_cache, logits (B, V)).

        ``cache["pos"]`` is a scalar (uniform batch) or a (B,) array of
        per-slot lengths (continuous batching); rotary positions, the KV
        write position and the attention valid-mask all follow it per slot.
        """
        cfg, rcfg, ctx = self.cfg, self.rcfg, self.ctx
        self._check_cache_layout(cache)
        B = tokens.shape[0]
        pos = cache["pos"]
        positions = jnp.broadcast_to(jnp.reshape(pos, (-1, 1)), (B, 1))
        x = T.embed_tokens(params["embed"], tokens, ctx, self.act_dtype)
        fam = cfg.family
        kv_kw = dict(kv_spec=self.kv_spec, kv_kernel=self.kv_use_kernel)
        if "pages" in cache:
            # paged decode (DESIGN.md §10): blocks read/write the page pool
            # through the per-slot block tables instead of per-slot rows
            self._require_pageable()
            kv_kw.update(pages=cache["pages"],
                         page_size=self._paged_page_size(cache))
        new_cache = dict(cache, pos=pos + 1)
        if fam == "dense":
            def body(h, lp, lc):
                y, kv = T.dense_block_forward(lp, h, cfg, ctx, rcfg,
                                              positions=positions, cache=lc,
                                              cache_pos=pos,
                                              use_kernel=self.use_kernel,
                                              **kv_kw)
                return y, kv
            x, new_kv = T.scan_blocks(body, x, params["blocks"], rcfg,
                                      cache=cache["kv"], length=cfg.n_layers)
            new_cache["kv"] = new_kv
        elif fam == "moe":
            def body(h, lp, lc):
                new_c = dict(lc)
                if "dense" in params["blocks"]:
                    kvs = []
                    for i in range(cfg.moe_every - 1):
                        dlp = jax.tree.map(lambda a: a[i], lp["dense"])
                        dlc = jax.tree.map(lambda a: a[i], lc["dense"])
                        h, kv = T.dense_block_forward(dlp, h, cfg, ctx, rcfg,
                                                      positions=positions,
                                                      cache=dlc, cache_pos=pos,
                                                      use_kernel=self.use_kernel,
                                                      **kv_kw)
                        kvs.append(kv)
                    new_c["dense"] = jax.tree.map(
                        lambda *xs: jnp.stack(xs), *kvs)
                h, kv = T.moe_block_forward(lp["moe"], h, cfg, ctx, rcfg,
                                            positions=positions, cache=lc["moe"],
                                            cache_pos=pos,
                                            use_kernel=self.use_kernel,
                                            **kv_kw)
                new_c["moe"] = kv
                return h, new_c
            blocks_cache = {"moe": cache["kv"]["moe"]}
            if "dense" in cache["kv"]:
                blocks_cache["dense"] = cache["kv"]["dense"]
            x, new_kv = T.scan_blocks(body, x, params["blocks"], rcfg,
                                      cache=blocks_cache, length=self.n_groups)
            new_cache["kv"] = new_kv
        elif fam == "encdec":
            def body(h, lp, lc):
                merged = {"k": lc["self"]["k"], "v": lc["self"]["v"],
                          "xk": lc["cross"]["k"], "xv": lc["cross"]["v"],
                          "xlen": cache["xlen"]}
                y, kv = T.decoder_xblock_forward(lp, h, cfg, ctx, rcfg,
                                                 positions=positions,
                                                 cache=merged, cache_pos=pos,
                                                 use_kernel=self.use_kernel)
                return y, {"self": kv, "cross": lc["cross"]}
            x, new_c = T.scan_blocks(body, x, params["blocks"], rcfg,
                                     cache={"self": cache["kv"], "cross": cache["cross"]},
                                     length=cfg.n_layers)
            new_cache["kv"] = new_c["self"]
            new_cache["cross"] = new_c["cross"]
        elif fam == "ssm":
            def body(h, lp, lc):
                y, nc = T.mamba_block_forward(lp, h, cfg, ctx, cache=lc,
                                              variant="mamba1",
                                              use_kernel=self.use_kernel)
                return y, nc
            x, new_ssm = T.scan_blocks(body, x, params["blocks"], rcfg,
                                       cache=cache["ssm"], length=cfg.n_layers)
            new_cache["ssm"] = new_ssm
        elif fam == "hybrid":
            chunks = self._hybrid_chunks()
            off = 0
            shared_new, ssm_new = [], []
            for ci, size in enumerate(chunks):
                lc = jax.tree.map(lambda a: a[ci], cache["shared_kv"])
                x, kv = T.dense_block_forward(params["shared"], x, cfg, ctx, rcfg,
                                              positions=positions, cache=lc,
                                              cache_pos=pos,
                                              use_kernel=self.use_kernel,
                                              **kv_kw)
                shared_new.append(kv)
                sub = jax.tree.map(lambda a: a[off:off + size], params["blocks"])
                subc = jax.tree.map(lambda a: a[off:off + size], cache["ssm"])
                def body(h, lp, lcc):
                    y, nc = T.mamba_block_forward(lp, h, cfg, ctx, cache=lcc,
                                                  variant="mamba2",
                                                  use_kernel=self.use_kernel)
                    return y, nc
                x, new_sub = T.scan_blocks(body, x, sub, rcfg, cache=subc, length=size)
                ssm_new.append(new_sub)
                off += size
            new_cache["shared_kv"] = jax.tree.map(lambda *xs: jnp.stack(xs), *shared_new)
            new_cache["ssm"] = jax.tree.map(lambda *xs: jnp.concatenate(xs), *ssm_new)
        x = rmsnorm(x[:, 0], params["ln_f"], cfg.norm_eps)
        w_un = params["embed"] if cfg.tie_embeddings else params["unembed"]
        if cfg.tie_embeddings:
            logits = matmul_param(x, jnp.swapaxes(param_value(w_un, x.dtype), 0, 1))
        else:
            logits = matmul_param(x, w_un, use_kernel=self.use_kernel)
        return new_cache, self.ctx.constrain(logits, "batch", "vocab")


def _cache_len(cache) -> int:
    if "kv" in cache:
        leaf = cache["kv"]["moe"]["k"] if isinstance(cache["kv"], dict) and "moe" in cache["kv"] \
            else cache["kv"]["k"]
        return leaf.shape[2]
    return 0


def kv_decode_bytes_per_token(cfg: ModelConfig, context_len: int,
                              kv_spec: Optional[QuantSpec] = None,
                              cache_dtype_bytes: int = 2) -> Dict[str, float]:
    """Modeled HBM bytes read from the KV cache per decoded token.

    Every decode step re-reads each attention layer's full valid K+V prefix
    — the S-proportional term that bounds decode throughput at long context
    (benchmarks/bench_roofline.py). Quantized caches stream byte-wide codes
    (``code_bytes``) plus an S-independent per-step scale read
    (``scale_bytes``: (B-slot share) 2 * G * Dh * 4 per layer, VMEM-resident
    in the fused kernel and negligible at depth); bf16 caches stream
    ``cache_dtype_bytes`` per element and no scales. pofx codes occupy one
    byte per element in HBM even though only N-1 bits carry information —
    bit-packing them is headroom this model does not claim (DESIGN.md §8).
    """
    fam = cfg.family
    if fam == "ssm":
        n_attn = 0
    elif fam == "hybrid":
        n_attn = -(-cfg.n_layers // cfg.attn_every) if cfg.attn_every else 0
    else:  # dense / moe / encdec self-attention layers
        n_attn = cfg.n_layers
    G, Dh = cfg.n_kv_heads, cfg.d_head
    per_elem = 1 if kv_spec is not None else cache_dtype_bytes
    return {
        "code_bytes": float(n_attn * 2 * G * context_len * Dh * per_elem),
        "scale_bytes": float(n_attn * 2 * G * Dh * 4) if kv_spec is not None
        else 0.0,
    }


def build_model(cfg: ModelConfig, rcfg: RunConfig, mesh=None,
                use_kernel: bool = False, kv_spec=None,
                kv_kernel: Optional[bool] = None) -> LM:
    ctx = make_ctx(mesh, sequence_parallel=rcfg.sequence_parallel)
    return LM(cfg, rcfg, ctx, use_kernel=use_kernel, kv_spec=kv_spec,
              kv_kernel=kv_kernel)


# ---------------------------------------------------------------------------
# Post-training quantization of a parameter tree (the paper's technique)
# ---------------------------------------------------------------------------

_NEVER_QUANT = ("ln", "norm", "A_log", "dt_bias", "D", "router", "conv_w",
                "conv_b", "q_norm", "k_norm")


def apply_policy(params, policy):
    """Convert weight matrices to QuantizedTensor storage per a QuantPolicy.

    ``policy`` is a QuantPolicy or policy string (see repro.core.policy).
    Each eligible leaf — a >=2D matmul weight: attention/MLP/MoE/SSM
    projections and embed/unembed — is matched against the policy's ordered
    path-glob rules; the first matching rule's spec decides its format.
    Norm scales, SSM recurrence params, conv taps and MoE router weights are
    never quantized regardless of rules (DESIGN.md §5), as is any leaf no
    rule matches or a "keep" rule claims. fp32/bf16 rules cast in place
    (no QuantizedTensor wrapper — the float fast path stays float).
    """
    if isinstance(policy, str):
        policy = QuantPolicy.from_string(policy)
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    out = []
    for path, leaf in flat:
        names = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
        name = "/".join(names)
        # layer-stacked leaves must keep per-layer scales (leading dims stay
        # mapped) so lax.scan can slice codes and scale together.
        stack_depth = 0
        if "blocks" in names or "enc_blocks" in names:
            stack_depth = 2 if "dense" in names else 1
        eligible = (leaf.ndim >= 2 + stack_depth
                    and not any(t in name for t in _NEVER_QUANT))
        spec = policy.match(name) if eligible else None
        if spec is None:
            out.append(leaf)
            continue
        if spec.kind in ("fp32", "bf16"):
            dt = jnp.float32 if spec.kind == "fp32" else jnp.bfloat16
            out.append(jnp.asarray(leaf).astype(dt))
            continue
        fn = lambda w: quantize(w.astype(jnp.float32), spec, axis=-1)
        for _ in range(stack_depth):
            fn = jax.vmap(fn)
        out.append(fn(jnp.asarray(leaf)))
    return jax.tree_util.tree_unflatten(treedef, out)


def quantize_params(params, spec: QuantSpec, *, quant_embed: bool = True):
    """Back-compat shim: uniform-policy application of one QuantSpec.

    Equivalent to ``apply_policy(params, QuantPolicy.uniform(spec))``, with
    ``quant_embed=False`` expressed as a leading "*embed*=keep" rule.
    """
    rules = (("*embed*", None),) if not quant_embed else ()
    return apply_policy(params, QuantPolicy(rules=rules + (("*", spec),)))


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for the dry-run (no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Abstract batch for one (arch, shape) cell.

    train/prefill: {tokens, labels[, frames]}; decode: {tokens (B,1)} —
    cache/params come from abstract_params / init_cache eval_shape.
    """
    B, Sq = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind in ("train", "prefill"):
        spec = {"tokens": jax.ShapeDtypeStruct((B, Sq), i32),
                "labels": jax.ShapeDtypeStruct((B, Sq), i32)}
        if cfg.family == "encdec":
            spec["frames"] = jax.ShapeDtypeStruct((B, Sq, cfg.d_model), jnp.bfloat16)
        return spec
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}

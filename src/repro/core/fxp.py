"""FxP(M, F) — two's-complement linear fixed-point quantization.

The paper's baseline scheme: M total bits, F fraction bits, value = code/2^F,
codes clamped to [-2^(M-1), 2^(M-1)-1]. Round-to-nearest-even via rint.

Also provides the *normalizer* scales used to bring LM weights into the
normalized range the paper assumes for ANN parameters: per-tensor or
per-channel max-|w| scaling, with a power-of-two option so the rescale is an
exact exponent shift (hardware-friendly; keeps PoFx bit-exactness intact).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "fxp_quantize",
    "fxp_dequantize",
    "fxp_quantize_np",
    "fxp_dequantize_np",
    "compute_scale",
]


def _q(x, M: int, F: int, xp):
    lo = -(1 << (M - 1))
    hi = (1 << (M - 1)) - 1
    if xp is np:
        scaled = np.rint(np.asarray(x, dtype=np.float64) * float(1 << F))
    else:
        scaled = jnp.round(jnp.asarray(x, dtype=jnp.float32) * float(1 << F))
    return xp.clip(scaled, lo, hi).astype(xp.int32)


def fxp_quantize(x, M: int, F: int) -> jax.Array:
    return _q(x, M, F, jnp)


def fxp_quantize_np(x, M: int, F: int) -> np.ndarray:
    return _q(x, M, F, np)


def fxp_dequantize(codes, F: int, dtype=jnp.float32) -> jax.Array:
    return jnp.asarray(codes).astype(dtype) * (1.0 / (1 << F))


def fxp_dequantize_np(codes, F: int) -> np.ndarray:
    return np.asarray(codes, dtype=np.float64) / float(1 << F)


def compute_scale(w, mode: str = "tensor_pow2", axis: int | None = None, eps: float = 1e-12):
    """Normalizer scale so that w/scale is within [-1, 1].

    mode: "none" (scale 1 — paper's assumption of already-normalized params),
          "tensor" | "tensor_pow2" | "channel" | "channel_pow2".
    ``axis`` is the *output-channel* axis kept distinct for channel modes.
    Returns an array broadcastable against w.
    """
    xp = jnp if isinstance(w, jax.Array) else np
    if mode == "none":
        return xp.ones((1,) * xp.asarray(w).ndim, dtype=xp.float32)
    a = xp.abs(xp.asarray(w))
    if mode.startswith("tensor"):
        s = xp.max(a)
        s = xp.maximum(s, eps)
        s = xp.reshape(s, (1,) * a.ndim)
    elif mode.startswith("channel"):
        if axis is None:
            raise ValueError("channel scale mode requires axis")
        red = tuple(i for i in range(a.ndim) if i != axis % a.ndim)
        s = xp.maximum(xp.max(a, axis=red, keepdims=True), eps)
    else:
        raise ValueError(f"unknown scale mode {mode!r}")
    if mode.endswith("pow2"):
        s = xp.exp2(xp.ceil(xp.log2(s)))
    return s.astype(xp.float32)

"""Serve-engine throughput under varying request-arrival mixes.

The continuous-batching claim: tokens/s should hold up when requests
arrive staggered (slots refill as others finish) instead of as one
aligned batch — the regime the old one-shot driver could not serve at
all. Three mixes over the same request set:

  burst     — all requests arrive at t=0 (best case for static batching)
  staggered — one request every `gap` decode steps (steady traffic)
  ragged    — burst arrivals but 2x-spread generation lengths (slots
              free at different times; continuous refill does the work)

Rows land in experiments/bench/serve_engine.csv. Run standalone
(``python -m benchmarks.bench_serve_engine [--use-kernel]``) or via
``benchmarks.run``.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCHS, RunConfig, smoke
from repro.launch.engine import Request, SamplingParams, ServeEngine
from repro.nn.models import apply_policy, build_model

from .common import write_csv

ARCH = "yi-9b"
N_REQ = 8
SLOTS = 4
PROMPT = 32
GEN = 16
CHUNK = 8


def _mix_requests(mix: str, vocab: int) -> list:
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(N_REQ):
        gen = GEN
        arrival = 0.0
        if mix == "staggered":
            arrival = float(i * (GEN // 2))
        elif mix == "ragged":
            gen = GEN // 2 if i % 2 else GEN
        reqs.append(Request(
            rid=i, prompt=rng.integers(0, vocab, PROMPT), max_new=gen,
            sampling=SamplingParams(), arrival=arrival))
    return reqs


def run(use_kernel: bool = False, quant: str = "pofx8"):
    cfg = smoke(ARCHS[ARCH])
    model = build_model(cfg, RunConfig(remat="none"), use_kernel=use_kernel)
    params = apply_policy(model.init(jax.random.PRNGKey(0)), quant)
    rng = np.random.default_rng(7)
    rows = []
    for mix in ("burst", "staggered", "ragged"):
        reqs = _mix_requests(mix, cfg.vocab_size)
        engine = ServeEngine(model, params, n_slots=SLOTS,
                             max_len=PROMPT + GEN, chunk=CHUNK, seed=0)
        # warmup on the SAME engine (jit caches are per-instance): compile
        # prefill + the chunk variants outside the timed run, else the
        # first mix absorbs all XLA compile time and the mix comparison
        # becomes a measurement artifact
        engine.run([Request(rid=1000 + i,
                            prompt=rng.integers(0, cfg.vocab_size, PROMPT),
                            max_new=GEN, sampling=SamplingParams())
                    for i in range(SLOTS)])
        engine.prefill_time = engine.decode_time = 0.0
        engine.decode_steps = 0
        engine.clock = 0.0  # warmup must not shift the measured arrivals
        warm_gen = engine.stats()["generated_tokens"]
        warm_sampled = engine.n_prefill_sampled
        engine.run(reqs)
        st = engine.stats()
        n_gen = st["generated_tokens"] - warm_gen
        n_dec = n_gen - (engine.n_prefill_sampled - warm_sampled)
        rows.append({
            "mix": mix, "arch": ARCH, "quant": quant,
            "use_kernel": use_kernel, "slots": SLOTS, "requests": N_REQ,
            "prompt_len": PROMPT, "gen": GEN,
            "generated_tokens": n_gen,
            "decode_steps": st["decode_steps"],
            "decode_tok_per_s": round(n_dec / max(st["decode_time_s"], 1e-9),
                                      2),
            "prefill_s": round(st["prefill_time_s"], 4),
            "decode_s": round(st["decode_time_s"], 4),
        })
    write_csv("serve_engine", rows)
    by_mix = {r["mix"]: r["decode_tok_per_s"] for r in rows}
    claims = {
        f"decode_tok_per_s[{m}]": v for m, v in by_mix.items()
    }
    claims["staggered_vs_burst_ratio"] = round(
        by_mix["staggered"] / max(by_mix["burst"], 1e-9), 3)
    return rows, claims


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--quant", default="pofx8")
    args = ap.parse_args(argv)
    rows, claims = run(use_kernel=args.use_kernel, quant=args.quant)
    for r in rows:
        print(r)
    for k, v in claims.items():
        print(f"serve_engine,{k},{v}")


if __name__ == "__main__":
    main()

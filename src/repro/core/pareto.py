"""Pareto-front + hypervolume utilities (Tables 3/4 methodology).

All objectives are MINIMIZED (callers negate accuracy-like objectives).
Hypervolume: exact sweep for 2D, recursive slicing for 3D+, measured against
a reference point that must dominate-be-dominated-by nothing (worse than all
points in every objective).
"""
from __future__ import annotations

import numpy as np

__all__ = ["pareto_mask", "pareto_front", "hypervolume", "hypervolume_gain"]


def pareto_mask(points: np.ndarray) -> np.ndarray:
    """Boolean mask of non-dominated rows (minimization)."""
    pts = np.asarray(points, dtype=np.float64)
    n = pts.shape[0]
    mask = np.ones(n, dtype=bool)
    for i in range(n):
        if not mask[i]:
            continue
        dominates_i = np.all(pts <= pts[i], axis=1) & np.any(pts < pts[i], axis=1)
        if np.any(dominates_i):
            mask[i] = False
            continue
        dominated_by_i = np.all(pts >= pts[i], axis=1) & np.any(pts > pts[i], axis=1)
        mask &= ~dominated_by_i
        mask[i] = True
    return mask


def pareto_front(points: np.ndarray) -> np.ndarray:
    return np.asarray(points)[pareto_mask(points)]


def _hv(front: np.ndarray, ref: np.ndarray) -> float:
    """Recursive hypervolume (minimization, exact)."""
    front = front[np.all(front < ref, axis=1)]
    if front.shape[0] == 0:
        return 0.0
    if front.shape[1] == 1:
        return float(ref[0] - front[:, 0].min())
    # Sort by first objective; sweep slices.
    order = np.argsort(front[:, 0])
    front = front[order]
    vol = 0.0
    prev = ref[0]
    # iterate from worst (largest) first objective to best
    for i in range(front.shape[0] - 1, -1, -1):
        x = front[i, 0]
        width = prev - x
        if width > 0:
            sub = front[: i + 1, 1:]
            vol += width * _hv(sub, ref[1:])
            prev = x
    return float(vol)


def hypervolume(points: np.ndarray, ref: np.ndarray) -> float:
    pts = np.asarray(points, dtype=np.float64)
    ref = np.asarray(ref, dtype=np.float64)
    if pts.size == 0:
        return 0.0
    return _hv(pareto_front(pts), ref)


def hypervolume_gain(base_points: np.ndarray, extra_points: np.ndarray, ref: np.ndarray) -> float:
    """% increase in hypervolume from adding ``extra_points`` (paper metric)."""
    base = hypervolume(base_points, ref)
    both = hypervolume(np.concatenate([np.asarray(base_points), np.asarray(extra_points)]), ref)
    if base <= 0:
        return float("inf") if both > 0 else 0.0
    return 100.0 * (both - base) / base

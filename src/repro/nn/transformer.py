"""Model stacks for every assigned family, built scan-over-layers.

One ``block_*`` triple (init / logical / forward) per block kind:

  dense   — RMSNorm -> GQA attention -> RMSNorm -> MLP        (llama-style)
  moe     — RMSNorm -> GQA attention -> RMSNorm -> MoE FFN
  mamba1  — RMSNorm -> mamba1 mixer                           (falcon-mamba)
  mamba2  — RMSNorm -> mamba2/SSD mixer                       (zamba2)
  encdec  — whisper-style encoder block / decoder block with cross-attention

Stacks scan over layer-stacked parameter pytrees (leading axis = n_layers)
so HLO size is depth-independent; ``remat="block"`` wraps the block body in
``jax.checkpoint`` during training. Caches are stacked along the same axis
and scanned together with the params during decode.

MoE interleaving (llama4: every other layer) is expressed as a scan over
*groups* of ``moe_every`` layers — (moe_every-1) dense blocks + 1 MoE block
per group — so mixed stacks still scan. The zamba2 hybrid applies ONE
shared attention block (single param set, n_apps KV caches) every
``attn_every`` mamba2 layers via python-chunked sub-scans.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import ssm
from .attention import attn_forward, attn_init, attn_logical
from .layers import dense_init, matmul_param, mlp_forward, mlp_init, mlp_logical, rmsnorm
from .moe import moe_forward, moe_init, moe_logical

# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def dense_block_init(key, cfg, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": attn_init(k1, cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def dense_block_logical(cfg) -> dict:
    return {"ln1": ("p_unsharded",), "attn": attn_logical(cfg),
            "ln2": ("p_unsharded",), "mlp": mlp_logical(cfg.act)}


def dense_block_forward(p, x, cfg, ctx, rcfg, *, positions, cache=None,
                        cache_pos=None, causal=True, xa=None, use_kernel=False,
                        kv_spec=None, kv_kernel=False, kv_scales=None,
                        pages=None, page_size=None, paged_prefill=None):
    h, new_kv = attn_forward(p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps), cfg,
                             ctx, rcfg, positions=positions, causal=causal,
                             cache=cache, cache_pos=cache_pos, xa=xa,
                             use_kernel=use_kernel, kv_spec=kv_spec,
                             kv_kernel=kv_kernel, kv_scales=kv_scales,
                             pages=pages, page_size=page_size,
                             paged_prefill=paged_prefill)
    x = x + h
    x = x + mlp_forward(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg.act,
                        ctx, use_kernel=use_kernel)
    return ctx.constrain(x, "batch", "seq", None), new_kv


def moe_block_init(key, cfg, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": attn_init(k1, cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "moe": moe_init(k2, cfg, dtype),
    }


def moe_block_logical(cfg) -> dict:
    return {"ln1": ("p_unsharded",), "attn": attn_logical(cfg),
            "ln2": ("p_unsharded",), "moe": moe_logical(cfg)}


def moe_block_forward(p, x, cfg, ctx, rcfg, *, positions, cache=None,
                      cache_pos=None, use_kernel=False,
                      kv_spec=None, kv_kernel=False, kv_scales=None,
                      pages=None, page_size=None, paged_prefill=None):
    h, new_kv = attn_forward(p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps), cfg,
                             ctx, rcfg, positions=positions, causal=True,
                             cache=cache, cache_pos=cache_pos,
                             use_kernel=use_kernel, kv_spec=kv_spec,
                             kv_kernel=kv_kernel, kv_scales=kv_scales,
                             pages=pages, page_size=page_size,
                             paged_prefill=paged_prefill)
    x = x + h
    x = x + moe_forward(p["moe"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg, ctx,
                        use_kernel=use_kernel)
    return ctx.constrain(x, "batch", "seq", None), new_kv


def mamba_block_init(key, cfg, dtype=jnp.float32) -> dict:
    init = ssm.mamba1_init if cfg.family == "ssm" else ssm.mamba2_init
    return {"ln": jnp.ones((cfg.d_model,), dtype), "mix": init(key, cfg, dtype)}


def mamba_block_logical(cfg) -> dict:
    log = ssm.mamba1_logical() if cfg.family == "ssm" else ssm.mamba2_logical()
    return {"ln": ("p_unsharded",), "mix": log}


def mamba_block_forward(p, x, cfg, ctx, *, cache=None, use_kernel=False,
                        variant="mamba1"):
    fwd = ssm.mamba1_forward if variant == "mamba1" else ssm.mamba2_forward
    h, new_cache = fwd(p["mix"], rmsnorm(x, p["ln"], cfg.norm_eps), cfg, ctx,
                       cache=cache, use_kernel=use_kernel)
    return ctx.constrain(x + h, "batch", "seq", None), new_cache


def encdec_block_init(key, cfg, dtype=jnp.float32, cross: bool = False) -> dict:
    p = dense_block_init(key, cfg, dtype)
    if cross:
        k = jax.random.fold_in(key, 7)
        p["ln_x"] = jnp.ones((cfg.d_model,), dtype)
        p["xattn"] = attn_init(k, cfg, dtype)
    return p


def encdec_block_logical(cfg, cross: bool = False) -> dict:
    p = dense_block_logical(cfg)
    if cross:
        p["ln_x"] = ("p_unsharded",)
        p["xattn"] = attn_logical(cfg)
    return p


def decoder_xblock_forward(p, x, cfg, ctx, rcfg, *, positions, xa=None,
                           cache=None, cache_pos=None, use_kernel=False):
    """Whisper decoder block: self-attn (+cache) -> cross-attn -> MLP."""
    self_cache = None if cache is None else {"k": cache["k"], "v": cache["v"]}
    h, new_kv = attn_forward(p["attn"], rmsnorm(x, p["ln1"], cfg.norm_eps), cfg,
                             ctx, rcfg, positions=positions, causal=True,
                             cache=self_cache, cache_pos=cache_pos,
                             use_kernel=use_kernel)
    x = x + h
    if cache is not None and "xk" in cache:
        xcache = {"k_static": cache["xk"], "v_static": cache["xv"],
                  "len": cache["xlen"]}
        h, _ = attn_forward(p["xattn"], rmsnorm(x, p["ln_x"], cfg.norm_eps), cfg,
                            ctx, rcfg, positions=positions, cache=xcache,
                            cache_pos=cache_pos, use_kernel=use_kernel)
    else:
        h, xkv = attn_forward(p["xattn"], rmsnorm(x, p["ln_x"], cfg.norm_eps),
                              cfg, ctx, rcfg, positions=positions, xa=xa,
                              use_kernel=use_kernel)
    x = x + h
    x = x + mlp_forward(p["mlp"], rmsnorm(x, p["ln2"], cfg.norm_eps), cfg.act,
                        ctx, use_kernel=use_kernel)
    return ctx.constrain(x, "batch", "seq", None), new_kv


# ---------------------------------------------------------------------------
# Stacked-layer utilities
# ---------------------------------------------------------------------------


def stack_init(block_init, key, n: int, *args, **kwargs):
    """vmap a per-layer init over n split keys -> leading layer axis."""
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: block_init(k, *args, **kwargs))(keys)


def stack_logical(block_logical) -> Any:
    """Prepend the 'layers' logical axis to every leaf of a block tree."""
    return jax.tree.map(lambda ax: ("layers", *ax), block_logical,
                        is_leaf=lambda x: isinstance(x, tuple))


def scan_blocks(body, x, stacked, rcfg, *, cache=None, length: int):
    """lax.scan over stacked layer params (+ optional stacked caches).

    body(x, layer_params, layer_cache) -> (x, new_layer_cache)
    Returns (x, new_stacked_cache). remat wraps the body when training.
    """
    fn = body
    if rcfg.remat == "block" and cache is None:
        fn = jax.checkpoint(body)

    def step(carry, xs):
        lp, lc = xs
        y, new_c = fn(carry, lp, lc)
        return y, new_c

    x, new_cache = jax.lax.scan(step, x, (stacked, cache), length=length)
    return x, new_cache


# ---------------------------------------------------------------------------
# Positional / embedding helpers
# ---------------------------------------------------------------------------


def sinusoid_table(max_len: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(max_len, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def embed_tokens(emb, tokens, ctx, dtype=jnp.bfloat16):
    """Vocab-parallel embedding lookup (one-hot matmul keeps GSPMD happy)."""
    from .layers import param_value
    table = param_value(emb, dtype)
    x = jnp.take(table, tokens, axis=0)
    return ctx.constrain(x, "batch", "seq", None)


def unembed(x, w, ctx, use_kernel=False):
    logits = matmul_param(x, w, use_kernel=use_kernel)
    return ctx.constrain(logits, "batch", "seq_attn", "vocab")

"""Posit(N, ES) codec — exact, vectorized, for N <= 16.

Implements the posit number system of Gustafson & Yonemoto [39] as used by
ExPAN(N)D: ``value = (-1)^s * (2^(2^ES))^k * 2^e * 1.f`` with two's-complement
handling of negative codes, regime run-length encoding of ``k``, an
MSB-aligned (zero-completed) exponent field, and NaR at ``10...0``.

Two implementations share one generic body:

* ``*_np``  — numpy, float64: the golden reference used by tests/benchmarks.
* jnp path — float32 (exact for N <= 16, ES <= 3: significand has <= 14
  fraction bits and the scale stays within float32 range), jit-friendly,
  no data-dependent control flow (static unrolled bit loops).

Codes are carried as int32 arrays holding the raw N-bit pattern in [0, 2^N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "posit_decode_np",
    "posit_decode",
    "posit_encode_np",
    "posit_encode",
    "posit_value_table",
    "posit_max",
    "posit_min_pos",
    "NAR",
]


def NAR(N: int) -> int:
    """The Not-a-Real code for an N-bit posit (1 followed by zeros)."""
    return 1 << (N - 1)


def _check_config(N: int, ES: int) -> None:
    if not (2 <= N <= 16):
        raise ValueError(f"posit N={N} unsupported (need 2..16)")
    if not (0 <= ES <= 4):
        raise ValueError(f"posit ES={ES} unsupported (need 0..4)")


def _decode_fields(c, N: int, ES: int, xp):
    """Shared field extraction. Returns (sign_bit, k, e, frac_window).

    ``frac_window`` is the fraction left-aligned in an (N-1)-bit window, i.e.
    fraction value = frac_window / 2^(N-1). Exponent bits cut off by the
    regime are completed with zeros (standard posit semantics).
    """
    c = xp.asarray(c).astype(xp.int32)
    mask_n = (1 << N) - 1
    mask_body = (1 << (N - 1)) - 1
    c = c & mask_n
    s = (c >> (N - 1)) & 1
    # A2: two's complement magnitude pattern for negative codes.
    body = xp.where(s == 1, (-c) & mask_n, c) & mask_body
    # Leading bit of the regime.
    r0 = (body >> (N - 2)) & 1
    # Count of leading bits equal to r0 (unrolled: N is static).
    x = xp.where(r0 == 1, (~body) & mask_body, body)
    m = xp.zeros_like(c)
    found = xp.zeros_like(c, dtype=bool)
    for i in range(N - 2, -1, -1):
        bit = (x >> i) & 1
        found = found | (bit == 1)
        m = m + xp.where(found, 0, 1).astype(xp.int32)
    k = xp.where(r0 == 0, -m, m - 1)
    # Drop sign(implicit)/regime(m)/terminator(1): remaining bits MSB-aligned
    # in the (N-1)-bit window; zeros shift in from the right, which implements
    # zero-completion of truncated exponent/fraction fields.
    aligned = (body << (m + 1)) & mask_body
    if ES > 0:
        e = aligned >> (N - 1 - ES) if (N - 1 - ES) >= 0 else aligned
        frac = (aligned << ES) & mask_body
    else:
        e = xp.zeros_like(c)
        frac = aligned
    return s, k, e, frac


def posit_decode_np(codes, N: int, ES: int) -> np.ndarray:
    """Golden float64 decode. Zero -> 0.0, NaR -> NaN."""
    _check_config(N, ES)
    c = np.asarray(codes).astype(np.int64) & ((1 << N) - 1)
    s, k, e, frac = _decode_fields(c.astype(np.int32), N, ES, np)
    scale = (k.astype(np.int64) << ES) + e
    sig = 1.0 + frac.astype(np.float64) / float(1 << (N - 1))
    val = np.where(s == 1, -1.0, 1.0) * np.exp2(scale.astype(np.float64)) * sig
    val = np.where(c == 0, 0.0, val)
    val = np.where(c == NAR(N), np.nan, val)
    return val


def posit_decode(codes, N: int, ES: int) -> jax.Array:
    """jnp float32 decode (exact for N <= 16); jit/vmap friendly."""
    _check_config(N, ES)
    c = jnp.asarray(codes).astype(jnp.int32) & ((1 << N) - 1)
    s, k, e, frac = _decode_fields(c, N, ES, jnp)
    scale = (k << ES) + e
    sig = 1.0 + frac.astype(jnp.float32) / float(1 << (N - 1))
    # Exact 2^scale: build the float32 bit pattern directly (jnp.exp2 is not
    # correctly rounded for float32). scale stays within normal range for
    # N <= 16, ES <= 3 (|scale| <= 120).
    pow2 = jax.lax.bitcast_convert_type(
        ((scale + 127) << 23).astype(jnp.int32), jnp.float32
    )
    val = jnp.where(s == 1, -1.0, 1.0) * pow2 * sig
    val = jnp.where(c == 0, 0.0, val)
    val = jnp.where(c == NAR(N), jnp.nan, val)
    return val


@functools.lru_cache(maxsize=64)
def posit_value_table(N: int, ES: int) -> np.ndarray:
    """float64 values of the non-negative posit codes [0, 2^(N-1)).

    Strictly increasing (posits order like two's-complement integers), with
    table[0] == 0. Computed once per (N, ES).
    """
    _check_config(N, ES)
    codes = np.arange(1 << (N - 1), dtype=np.int64)
    vals = posit_decode_np(codes, N, ES)
    vals[0] = 0.0
    assert np.all(np.diff(vals) > 0), "posit value table must be monotonic"
    return vals


def posit_max(N: int, ES: int) -> float:
    return float(posit_value_table(N, ES)[-1])


def posit_min_pos(N: int, ES: int) -> float:
    return float(posit_value_table(N, ES)[1])


def _encode_impl(x, N: int, ES: int, xp, table, allow_zero: bool):
    a = xp.abs(x)
    L = 1 << (N - 1)
    idx = xp.clip(xp.searchsorted(table, a), 0, L - 1)
    lo = xp.clip(idx - 1, 0, L - 1)
    hi = idx
    dlo = a - table[lo]
    dhi = table[hi] - a
    # Nearest; ties -> even code (one of two consecutive codes is even).
    take_lo = (dlo < dhi) | ((dlo == dhi) & (lo % 2 == 0))
    code = xp.where(take_lo, lo, hi).astype(xp.int32)
    if not allow_zero:
        # Posit standard: nonzero values never round to zero (minpos floor).
        code = xp.where((a > 0) & (code == 0), 1, code)
    # Saturate above maxpos (searchsorted already clamped to L-1).
    neg = x < 0
    code = xp.where(neg, (-code) & ((1 << N) - 1), code)
    code = xp.where(a == 0, 0, code)
    code = xp.where(xp.isnan(x), NAR(N), code)
    return code


def posit_encode_np(x, N: int, ES: int, allow_zero: bool = True) -> np.ndarray:
    """Round float64 values to nearest posit code (ties to even code)."""
    _check_config(N, ES)
    table = posit_value_table(N, ES)
    return _encode_impl(np.asarray(x, dtype=np.float64), N, ES, np, table, allow_zero)


def posit_encode(x, N: int, ES: int, allow_zero: bool = True) -> jax.Array:
    """jnp encode; table is closed over as a constant (2^(N-1) floats)."""
    _check_config(N, ES)
    table = jnp.asarray(posit_value_table(N, ES), dtype=jnp.float32)
    return _encode_impl(jnp.asarray(x, dtype=jnp.float32), N, ES, jnp, table, allow_zero)


def posit_encode_arith(x, N: int, ES: int) -> jax.Array:
    """Gather-free posit encode: pure lane-wise bit arithmetic (softposit
    style round-to-nearest-even in code space).

    This is the TPU-native encoder: no table lookups (the searchsorted
    encoder's gathers do not partition under manual-axis shard_map — XLA
    PartitionGather aborts), just float32 bit dissection + integer RNE.
    Used by the gradient-compression transport; agrees with the canonical
    table encoder to <= 1 ulp of the code lattice (ties at regime
    boundaries may legally differ — bit-level RNE vs real-nearest).
    """
    _check_config(N, ES)
    xf = jnp.asarray(x, jnp.float32)
    bits = jax.lax.bitcast_convert_type(xf, jnp.int32)
    a_bits = bits & 0x7FFFFFFF
    a = jax.lax.bitcast_convert_type(a_bits, jnp.float32)
    e = ((a_bits >> 23) & 0xFF) - 127                    # floor(log2 a)
    frac23 = a_bits & 0x7FFFFF
    k = e >> ES                                          # floor division
    exp_f = e - (k << ES)                                # in [0, 2^ES)
    k_c = jnp.clip(k, -(N - 2), N - 2)
    # regime: k >= 0 -> (k+1) ones then 0 (len k+2); k < 0 -> (-k-1) zeros
    # then 1 (len -k+1)
    r_len = jnp.where(k_c >= 0, k_c + 2, 1 - k_c)
    regime = jnp.where(k_c >= 0, (2 << jnp.clip(k_c + 1, 0, 30)) - 2, 1)
    w = jnp.clip(N - 1 - r_len, 0, N - 1)                # tail bits kept
    tail = (exp_f << 23) | frac23                        # ES+23 bits
    shift_r = jnp.clip(ES + 23 - w, 0, 31)
    body = (regime << w) | (tail >> shift_r)
    # RNE on the dropped bits; integer carry IS correct posit rounding
    # (codes are ordered), including carries into the regime.
    rbit = jnp.where(shift_r > 0, (tail >> jnp.clip(shift_r - 1, 0, 31)) & 1, 0)
    sticky = jnp.where(
        shift_r > 1, (tail & ((1 << jnp.clip(shift_r - 1, 0, 31)) - 1)) != 0,
        False)
    lsb = body & 1
    body = body + (rbit & (sticky | (lsb == 1)).astype(jnp.int32))
    maxpos_code = (1 << (N - 1)) - 1
    body = jnp.clip(body, 0, maxpos_code)
    # sub-minpos handling: nearest of {0, minpos} (allow_zero semantics)
    minpos = float(posit_min_pos(N, ES))
    body = jnp.where(a < minpos / 2, 0, jnp.where(a < minpos,
                                                  jnp.maximum(body, 1), body))
    # super-maxpos saturates
    maxpos = float(posit_max(N, ES))
    body = jnp.where(a >= maxpos, maxpos_code, body)
    neg = bits < 0
    code = jnp.where(neg, (-body) & ((1 << N) - 1), body)
    code = jnp.where(a == 0, 0, code)
    code = jnp.where(jnp.isnan(xf), NAR(N), code)
    return code.astype(jnp.int32)

"""QuantPolicy — per-layer mixed-precision quantization with one spec grammar.

The paper's headline savings come from *choosing formats per tensor class*
(Table 6), not from one global format. This module is the single
configuration surface for that choice:

Spec-string grammar (``parse_spec`` / ``format_spec``, DESIGN.md §3):

    fp32 | bf16                       passthrough baselines
    fxp{M}[f{F}]                      FxP(M, F); F defaults to M-1
    posit{N}[es{ES}]                  Posit(N, ES); ES defaults to 2
    pofx{N}[es{ES}][m{M}][-direct]    the paper's format: normalized
                                      Posit(N-1, ES) storage, FxP(M, M-1)
                                      compute; M defaults to 8, path to
                                      via_fxp ("-viafxp")
    keep                              leave the tensor untouched

    optional scale suffix on any quantized kind:
        @channel (default) | @tensor | @none   -> scale_mode

Policy grammar (``QuantPolicy.from_string``):

    "pofx8es2"                                   uniform (sugar for "*=...")
    "attn/*=pofx8es2,mlp/*=fxp8f7,*=bf16"        ordered (glob -> spec) rules
    "paper-table6"                               named preset (PRESETS)

Rules match parameter pytree paths ("/"-joined dict keys, e.g.
"blocks/attn/wq"); the first matching rule wins and a pattern is anchored at
a path-segment boundary (pattern "attn/*" behaves like "**/attn/*"). Tensor
classes on the never-quantize list (norms, SSM recurrence, routers — see
DESIGN.md §5) are excluded *before* rule matching and cannot be quantized by
any rule.

``apply_policy`` itself lives in ``repro.nn.models`` (it owns the
stacked-block layout); everything format-related is here so core stays free
of nn imports.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import re
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from .quantizers import (QuantSpec, QuantizedTensor, storage_bits,
                         validate_kv_spec)

__all__ = [
    "parse_spec",
    "format_spec",
    "QuantPolicy",
    "PRESETS",
    "KV_RULE",
    "parse_kv_spec",
    "storage_report",
    "policy_from_pareto",
    "add_policy_arg",
    "add_kv_quant_arg",
    "resolve_kv_spec",
    "validate_scale_sharding",
]

# Reserved rule name: "kv=<spec>" configures the decode KV-cache format
# instead of matching a parameter path (DESIGN.md §8). It rides in the same
# policy string ("attn/*=pofx8es2,kv=fxp8,*=bf16") so one --quant value can
# describe weights AND cache, but it never participates in path matching.
KV_RULE = "kv"

_SCALE_TOKENS = {"channel": "channel_pow2", "tensor": "tensor_pow2",
                 "none": "none"}
_SCALE_NAMES = {v: k for k, v in _SCALE_TOKENS.items()}

_FXP_RE = re.compile(r"^fxp(\d+)(?:f(\d+))?$")
_POSIT_RE = re.compile(r"^posit(\d+)(?:es(\d+))?$")
_POFX_RE = re.compile(r"^pofx(\d+)(?:es(\d+))?(?:m(\d+))?(?:-(direct|viafxp))?$")

GRAMMAR_HELP = (
    "spec grammar: fp32 | bf16 | fxp{M}[f{F}] | posit{N}[es{ES}] | "
    "pofx{N}[es{ES}][m{M}][-direct] | keep, each with optional "
    "@channel|@tensor|@none scale suffix; policy grammar: one spec "
    "(uniform) or comma-separated glob=spec rules matched first-wins "
    "against parameter paths (e.g. 'attn/*=pofx8es2,mlp/*=fxp8f7,*=bf16'), "
    "plus an optional 'kv=<spec>' rule naming the decode KV-cache format "
    "(fxp/pofx, byte-wide codes), or a preset name (%s)"
)


def parse_kv_spec(s: str) -> Optional[QuantSpec]:
    """Parse + validate one KV-cache spec string ("keep"/bf16/fp32 -> None)."""
    return validate_kv_spec(parse_spec(s))


def parse_spec(s: str) -> Optional[QuantSpec]:
    """Parse one spec string; returns None for the "keep" sentinel."""
    tok = s.strip().lower()
    if tok in ("keep", "skip"):
        return None
    scale_mode = None
    if "@" in tok:
        tok, _, sm = tok.partition("@")
        if sm not in _SCALE_TOKENS:
            raise ValueError(
                f"unknown scale mode {sm!r} in spec {s!r} "
                f"(expected one of {sorted(_SCALE_TOKENS)})")
        scale_mode = _SCALE_TOKENS[sm]
    if tok in ("fp32", "f32", "float32"):
        return QuantSpec(kind="fp32")
    if tok in ("bf16", "bfloat16"):
        return QuantSpec(kind="bf16")
    kw = {} if scale_mode is None else {"scale_mode": scale_mode}
    m = _FXP_RE.match(tok)
    if m:
        M = int(m.group(1))
        F = int(m.group(2)) if m.group(2) else M - 1
        return QuantSpec(kind="fxp", M=M, F=F, **kw)
    m = _POSIT_RE.match(tok)
    if m:
        N = int(m.group(1))
        ES = int(m.group(2)) if m.group(2) else 2
        return QuantSpec(kind="posit", N=N, ES=ES, **kw)
    m = _POFX_RE.match(tok)
    if m:
        N = int(m.group(1))
        ES = int(m.group(2)) if m.group(2) else 2
        M = int(m.group(3)) if m.group(3) else 8
        path = "direct" if m.group(4) == "direct" else "via_fxp"
        return QuantSpec(kind="pofx", N=N, ES=ES, M=M, path=path, **kw)
    raise ValueError(f"cannot parse quant spec {s!r} ({GRAMMAR_HELP % '...'})")


def format_spec(spec: Optional[QuantSpec]) -> str:
    """Canonical spec string; ``parse_spec(format_spec(s)) == s`` for every
    spec expressible in the grammar (kind/N/ES/M/F/path/scale_mode)."""
    if spec is None:
        return "keep"
    if spec.kind in ("fp32", "bf16"):
        return spec.kind
    if spec.kind == "fxp":
        out = f"fxp{spec.M}" + (f"f{spec.F}" if spec.F != spec.M - 1 else "")
    elif spec.kind == "posit":
        out = f"posit{spec.N}es{spec.ES}"
    else:  # pofx
        out = f"pofx{spec.N}es{spec.ES}"
        if spec.M != 8:
            out += f"m{spec.M}"
        if spec.path == "direct":
            out += "-direct"
    if spec.scale_mode != "channel_pow2":
        out += "@" + _SCALE_NAMES.get(spec.scale_mode, spec.scale_mode)
    return out


def _match_one(pattern: str, name: str) -> bool:
    """Glob match anchored at a path-segment boundary ("attn/*" behaves as
    "**/attn/*"; "embed" matches the top-level leaf only)."""
    return (fnmatch.fnmatchcase(name, pattern)
            or fnmatch.fnmatchcase(name, "*/" + pattern))


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Ordered (path-glob -> QuantSpec) rules; first match wins.

    A spec of None ("keep") leaves matching tensors untouched. Paths that
    match no rule are also left untouched, so a trailing "*" rule is the
    uniform fallback. A rule whose pattern is the reserved name ``kv`` is
    not a path rule at all: it names the decode KV-cache format
    (``kv_spec``) and is skipped by parameter matching.
    """
    rules: Tuple[Tuple[str, Optional[QuantSpec]], ...]

    @classmethod
    def uniform(cls, spec) -> "QuantPolicy":
        if isinstance(spec, str):
            spec = parse_spec(spec)
        return cls(rules=(("*", spec),))

    @classmethod
    def from_string(cls, s: str) -> "QuantPolicy":
        text = s.strip()
        if text in PRESETS:
            text = PRESETS[text]
        rules: List[Tuple[str, Optional[QuantSpec]]] = []
        seen_kv = False
        for part in text.split(","):
            part = part.strip()
            if not part:
                continue
            if "=" in part:
                pat, _, spec_s = part.partition("=")
                pat = pat.strip()
                if pat == KV_RULE:
                    if seen_kv:
                        raise ValueError(
                            f"duplicate kv= rule in policy {s!r}")
                    seen_kv = True
                    rules.append((KV_RULE, validate_kv_spec(parse_spec(spec_s))))
                else:
                    rules.append((pat, parse_spec(spec_s)))
            else:
                # bare spec: uniform sugar, equivalent to "*=<spec>"
                rules.append(("*", parse_spec(part)))
        if not rules:
            raise ValueError(f"empty quant policy {s!r}")
        return cls(rules=tuple(rules))

    def to_string(self) -> str:
        if len(self.rules) == 1 and self.rules[0][0] == "*":
            return format_spec(self.rules[0][1])
        return ",".join(f"{pat}={format_spec(spec)}"
                        for pat, spec in self.rules)

    @property
    def kv_spec(self) -> Optional[QuantSpec]:
        """The decode KV-cache format from a ``kv=<spec>`` rule (or None)."""
        for pat, spec in self.rules:
            if pat == KV_RULE:
                return spec
        return None

    def match_rule(self, name: str) -> Optional[Tuple[str, Optional[QuantSpec]]]:
        """First (pattern, spec) rule matching a "/"-joined parameter path.

        The reserved ``kv`` rule configures the cache, not a parameter, and
        never matches a path.
        """
        for pat, spec in self.rules:
            if pat == KV_RULE:
                continue
            if _match_one(pat, name):
                return (pat, spec)
        return None

    def match(self, name: str) -> Optional[QuantSpec]:
        rule = self.match_rule(name)
        return rule[1] if rule else None


# Named presets — resolved by QuantPolicy.from_string. "paper-table6" is the
# paper's winning deployment point (Table 6: PoFx(7,2) storage everywhere the
# datapath allows) with the error-sensitive embedding tables kept bf16, the
# per-layer mixing Langroudi/Gohil motivate.
PRESETS: Dict[str, str] = {
    "uniform-pofx8": "*=pofx8es2",
    "uniform-fxp8": "*=fxp8f7",
    "uniform-posit8": "*=posit8es2",
    "paper-table6": "embed=bf16,unembed=bf16,*=pofx8es2",
    # Table-6 weights + the quantized decode KV cache (DESIGN.md §8): the
    # whole serving HBM story — weight codes AND cache codes — in one string.
    "paper-table6-kv8": "embed=bf16,unembed=bf16,kv=fxp8,*=pofx8es2",
}


# ---------------------------------------------------------------------------
# Tensor-parallel sharding validity (DESIGN.md §9)
# ---------------------------------------------------------------------------


def validate_scale_sharding(name: str, codes_shape, scale_shape, codes_spec):
    """Scale PartitionSpec for a QuantizedTensor whose codes shard as
    ``codes_spec`` — the sharding-validity check for per-channel scales.

    A quantized leaf may shard along an axis only if its scale leaf is
    *congruent* there: broadcast (size 1 — per-tensor, or per-channel along
    a different axis) or exactly per-channel along the sharded axis (same
    size as the codes dim, e.g. an MLP up-projection's (1, d_ff) scale
    sharded with its (d, d_ff) codes). Anything else — a scale that varies
    along the sharded axis at a different granularity — cannot be split
    consistently with its codes and raises. Scales align against codes
    like NumPy broadcasting (trailing dims), so a lower-rank scale simply
    replicates over the missing leading dims.
    """
    from jax.sharding import PartitionSpec as P

    spec = tuple(codes_spec) + (None,) * (len(codes_shape) - len(codes_spec))
    if len(scale_shape) > len(codes_shape):
        raise ValueError(
            f"cannot shard quantized leaf {name!r}: scale rank "
            f"{len(scale_shape)} exceeds codes rank {len(codes_shape)}")
    off = len(codes_shape) - len(scale_shape)
    out = []
    for j, sdim in enumerate(scale_shape):
        i = j + off
        axis = spec[i]
        if axis is None or sdim == 1:
            out.append(None)
        elif sdim == codes_shape[i]:
            out.append(axis)            # per-channel scale shards with codes
        else:
            raise ValueError(
                f"cannot shard quantized leaf {name!r} along dim {i}: the "
                f"per-channel scale has size {sdim} there but the codes "
                f"have {codes_shape[i]} — the scale axis must match the "
                f"sharded axis exactly (or broadcast with size 1)")
    return P(*out)


# ---------------------------------------------------------------------------
# Policy-aware storage report (the paper's Table 6 storage rows, per rule)
# ---------------------------------------------------------------------------


def _leaf_entries(params):
    """(path-name, leaf) pairs with QuantizedTensor treated as one leaf."""
    import jax

    flat = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, QuantizedTensor))[0]
    out = []
    for path, leaf in flat:
        names = [str(getattr(k, "key", getattr(k, "name", k))) for k in path]
        out.append(("/".join(names), leaf))
    return out


def _leaf_stats(leaf) -> Tuple[int, int, str]:
    """(param count, stored bits, format label) for one leaf."""
    if isinstance(leaf, QuantizedTensor):
        n = int(np.prod(leaf.codes.shape)) if leaf.codes.ndim else 1
        return n, storage_bits(leaf), format_spec(leaf.spec)
    n = int(leaf.size)
    return n, n * leaf.dtype.itemsize * 8, str(leaf.dtype)


def storage_report(params, policy: Optional[QuantPolicy] = None) -> str:
    """Per-rule parameter-storage breakdown plus the total footprint.

    With a policy, leaves are grouped by the rule that claimed them
    (unmatched / never-quant leaves land in "(unmatched)"); without one,
    they are grouped by their storage format.
    """
    groups: Dict[str, List[Tuple[int, int]]] = {}
    fmt_by_group: Dict[str, set] = {}
    total_bits = 0
    total_n = 0
    for name, leaf in _leaf_entries(params):
        n, bits, fmt = _leaf_stats(leaf)
        if policy is not None:
            rule = policy.match_rule(name)
            key = f"{rule[0]}={format_spec(rule[1])}" if rule else "(unmatched)"
        else:
            key = fmt
        groups.setdefault(key, []).append((n, bits))
        fmt_by_group.setdefault(key, set()).add(fmt)
        total_bits += bits
        total_n += n
    lines = []
    for key, entries in sorted(groups.items(), key=lambda kv: -sum(
            b for _, b in kv[1])):
        n = sum(e[0] for e in entries)
        bits = sum(e[1] for e in entries)
        stored = ",".join(sorted(fmt_by_group[key]))
        lines.append(f"  {key:<28} {n/1e6:9.2f}M params  "
                     f"{bits/8/2**20:9.2f}MiB  {bits/max(n,1):5.2f} b/w  "
                     f"[{stored}]")
    bpw = total_bits / max(total_n, 1)
    lines.append(f"  {'TOTAL':<28} {total_n/1e6:9.2f}M params  "
                 f"{total_bits/8/2**20:9.2f}MiB  {bpw:5.2f} b/w  "
                 f"(vs fp32 {32/bpw:.1f}x, vs bf16 {16/bpw:.1f}x smaller)")
    return "\n".join(lines)


# ---------------------------------------------------------------------------
# Analysis-driven policy search (Fig. 8 / Tables 3-4 machinery -> a policy)
# ---------------------------------------------------------------------------


def policy_from_pareto(
    group_weights: Mapping[str, Sequence],
    candidates: Optional[Sequence[QuantSpec]] = None,
    *,
    max_avg_rel: float = 0.05,
    fallback: str = "bf16",
) -> QuantPolicy:
    """Pick one format per layer group from its (error, storage) Pareto front.

    group_weights: ordered {path-glob: [weight arrays]} — e.g.
        {"attn/*": [...], "mlp/*": [...]} sampled from the model.
    candidates: QuantSpecs to sweep (default: core.analysis grid, via_fxp
        paths only — the deployable ones per Table 5).
    For each group, candidates are reduced to their Pareto front over
    (avg relative weight error, stored bits/weight); the chosen spec is the
    cheapest front member with error <= max_avg_rel, else the most accurate
    front member. A trailing "*"=fallback rule completes the policy.
    """
    from .analysis import default_spec_grid, weight_error
    from .pareto import pareto_mask

    if candidates is None:
        candidates = [s for s in default_spec_grid(include_paths=False)
                      if s.kind != "posit" or s.N >= 6]
    rules: List[Tuple[str, Optional[QuantSpec]]] = []
    for pattern, weights in group_weights.items():
        pts = []
        for spec in candidates:
            errs, bits, count = [], 0, 0
            for w in weights:
                e = weight_error(w, spec, axis=-1)
                errs.append(e["avg_rel"])
                bits += e["bits"]
                count += int(np.prod(np.shape(w)))
            pts.append((float(np.mean(errs)), bits / max(count, 1)))
        pts_arr = np.asarray(pts)
        front_idx = np.nonzero(pareto_mask(pts_arr))[0]
        ok = [i for i in front_idx if pts_arr[i, 0] <= max_avg_rel]
        if ok:
            pick = min(ok, key=lambda i: (pts_arr[i, 1], pts_arr[i, 0]))
        else:
            pick = min(front_idx, key=lambda i: (pts_arr[i, 0], pts_arr[i, 1]))
        rules.append((pattern, candidates[pick]))
    rules.append(("*", parse_spec(fallback)))
    return QuantPolicy(rules=tuple(rules))


# ---------------------------------------------------------------------------
# Shared CLI path — every driver registers --quant through here
# ---------------------------------------------------------------------------


def add_policy_arg(parser, default: str = "pofx8es2", flag: str = "--quant",
                   extra_help: str = "") -> None:
    """Register the shared quantization-policy CLI argument.

    The value is a policy string (parse with ``QuantPolicy.from_string``);
    drivers with sentinel values ("auto") check those before parsing.
    """
    help_text = GRAMMAR_HELP % ", ".join(sorted(PRESETS))
    if extra_help:
        help_text = f"{extra_help}; {help_text}"
    parser.add_argument(flag, default=default, help=help_text)


def add_kv_quant_arg(parser, default: str = "auto",
                     flag: str = "--kv-quant") -> None:
    """Register the shared decode-KV-cache format argument.

    "auto" defers to the policy string's ``kv=`` rule (none -> unquantized
    bf16 cache); "none"/"bf16" force an unquantized cache; anything else is
    one spec (``parse_kv_spec``: fxp/pofx, byte-wide codes), e.g. "fxp8" or
    "pofx8es2".
    """
    parser.add_argument(
        flag, default=default,
        help="decode KV-cache format: auto (use the policy's kv= rule), "
             "none/bf16 (unquantized), or one byte-wide fxp/pofx spec "
             "(e.g. fxp8, pofx8es2); see DESIGN.md §8")


def resolve_kv_spec(kv_arg: str, policy: "QuantPolicy") -> Optional[QuantSpec]:
    """Combine a --kv-quant value with a policy's kv= rule (flag wins)."""
    tok = (kv_arg or "auto").strip().lower()
    if tok == "auto":
        return policy.kv_spec
    if tok in ("none", "off"):
        return None
    return parse_kv_spec(tok)

"""PoFx (Algorithm 1) tests: exhaustive bit-level equality with the golden
float decode, normalized-variant semantics (unidirectional shift, -1 OF),
LUT consistency, and jnp==numpy parity."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    norm_decode_np,
    pofx_convert,
    pofx_convert_np,
    pofx_lut,
    pofx_norm_lut,
    pofx_normalized,
    pofx_normalized_np,
    posit_decode_np,
)

CONFIGS = [(N, ES) for N in range(4, 11) for ES in range(0, 4)]


def _gold(vals, M, F):
    g = np.trunc(np.nan_to_num(vals) * (1 << F))
    return np.clip(g, -(1 << (M - 1)) + 1, (1 << (M - 1)) - 1)


@pytest.mark.parametrize("N,ES", CONFIGS)
@pytest.mark.parametrize("M,F", [(8, 7), (16, 12), (20, 10), (32, 20)])
def test_pofx_exhaustive_vs_golden(N, ES, M, F):
    codes = np.arange(1 << N)
    vals = posit_decode_np(codes, N, ES)
    out, of = pofx_convert_np(codes, N, ES, M, F)
    assert np.array_equal(out, _gold(vals, M, F))
    # OF flag set exactly when the *truncated* magnitude exceeds the output
    # range (hardware semantics: high bits shifted out, not pre-truncation).
    finite = ~np.isnan(vals)
    overflow = np.trunc(np.abs(np.nan_to_num(vals)) * (1 << F)) > ((1 << (M - 1)) - 1)
    assert np.array_equal(of[finite], overflow[finite])


@pytest.mark.parametrize("N,ES", [(8, 2), (6, 0), (16, 3), (10, 1)])
def test_pofx_jnp_matches_np(N, ES):
    c = np.arange(1 << N)
    o1, f1 = pofx_convert_np(c, N, ES, 16, 14)
    o2, f2 = pofx_convert(jnp.asarray(c), N, ES, 16, 14)
    assert np.array_equal(o1, np.asarray(o2))
    assert np.array_equal(f1, np.asarray(f2))


@pytest.mark.parametrize("N,ES", CONFIGS)
def test_pofx_normalized_exhaustive(N, ES):
    """Normalized variant: F = M-1, truncation, -1 saturates with OF."""
    M = 8
    nm = np.arange(1 << (N - 1))
    out, of = pofx_normalized_np(nm, N, ES, M)
    vals = norm_decode_np(nm, N, ES)
    assert np.array_equal(out, _gold(vals, M, M - 1))
    # -1 is in the normalized lattice but not extractable (paper §4.1.2)
    neg1 = vals == -1.0
    assert np.all(of[neg1])
    assert np.all(out[neg1] == -((1 << (M - 1)) - 1))
    # everything else is in range, no overflow
    assert not np.any(of[~neg1])
    # unidirectional: no output magnitude exceeds 2^(M-1)-1 and all
    # magnitudes strictly below 1.0 in fixed-point
    assert np.all(np.abs(out) <= (1 << (M - 1)) - 1)


def test_pofx_normalized_jnp_matches_np():
    nm = np.arange(1 << 7)
    o1, f1 = pofx_normalized_np(nm, 8, 2, 8)
    o2, f2 = pofx_normalized(jnp.asarray(nm), 8, 2, 8)
    assert np.array_equal(o1, np.asarray(o2))
    assert np.array_equal(f1, np.asarray(f2))


@pytest.mark.parametrize("N,ES", [(8, 2), (7, 1), (6, 0)])
def test_luts_match_bitlevel(N, ES):
    lut = pofx_lut(N, ES, 16, 14)
    out, _ = pofx_convert_np(np.arange(1 << N), N, ES, 16, 14)
    assert np.array_equal(lut, out)
    nlut = pofx_norm_lut(N, ES, 8)
    nout, _ = pofx_normalized_np(np.arange(1 << (N - 1)), N, ES, 8)
    assert np.array_equal(nlut, nout)


def test_truncation_vs_nearest_bias():
    """Stage-D truncation has a systematic negative magnitude bias; the
    beyond-paper 'nearest' knob removes most of it (sanity for Table 5's
    Posit_FxP degradation mechanism)."""
    N, ES, M = 8, 2, 8
    nm = np.arange(1 << (N - 1))
    vals = norm_decode_np(nm, N, ES)
    t, _ = pofx_normalized_np(nm, N, ES, M, rounding="trunc")
    r, _ = pofx_normalized_np(nm, N, ES, M, rounding="nearest")
    err_t = np.abs(t / (1 << (M - 1)) - vals).mean()
    err_r = np.abs(r / (1 << (M - 1)) - vals).mean()
    assert err_r <= err_t

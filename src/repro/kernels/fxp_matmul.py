"""Pallas TPU kernel: FxP MAC — int8 x int8 -> int32 accumulate.

The paper's fixed-point MAC baseline (M x M multiplier + 3M-bit accumulator,
Fig. 7) on the MXU's native int8 path. Output is the raw int32 accumulator
(the "3N-bit more precise output" the paper highlights vs posit-only MACs) or
a bf16 value rescaled by (x_scale * w_scale) when scales are supplied.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import default_blocks, vmem_scratch

__all__ = ["fxp_matmul"]


def _kernel(a_ref, b_ref, o_ref, acc_ref, *, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.int32), b_ref[...].astype(jnp.int32),
                            preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("blocks", "interpret"))
def fxp_matmul(a: jax.Array, b: jax.Array, blocks=None,
               interpret: bool | None = None) -> jax.Array:
    """a:(m,k) int8 @ b:(k,n) int8 -> (m,n) int32."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    if blocks is None:
        blocks = default_blocks()
    m, kdim = a.shape
    _, n = b.shape
    bm, bn, bk = (min(blocks[0], m), min(blocks[1], n), min(blocks[2], kdim))
    pm, pn, pk = (-m) % bm, (-n) % bn, (-kdim) % bk
    ap = jnp.pad(a, ((0, pm), (0, pk)))
    bp = jnp.pad(b, ((0, pk), (0, pn)))
    grid = (ap.shape[0] // bm, bp.shape[1] // bn, ap.shape[1] // bk)
    out = pl.pallas_call(
        functools.partial(_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ap.shape[0], bp.shape[1]), jnp.int32),
        scratch_shapes=[vmem_scratch((bm, bn), jnp.int32)],
        interpret=interpret,
    )(ap, bp)
    return out[:m, :n]

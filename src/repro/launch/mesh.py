"""Production mesh construction (functions only — importing this module
never touches jax device state).

Single pod: 256 chips as (16, 16) ("data", "model").
Multi pod:  2 pods x 256 chips as (2, 16, 16) ("pod", "data", "model");
the "pod" axis crosses DCN — gradient all-reduce (optionally posit8-
compressed, runtime/compression.py) is the only traffic on it.
"""
from __future__ import annotations

from typing import Optional

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 1,
                   devices=None):
    """Small mesh over whatever devices exist (tests / examples)."""
    shape = (pod, data, model) if pod > 1 else (data, model)
    axes = ("pod", "data", "model") if pod > 1 else ("data", "model")
    return jax.make_mesh(shape, axes, devices=devices)

"""Config system: model architectures, input shapes, quantization, run opts.

Every assigned architecture is one ``ModelConfig`` in this package (exact
numbers from the assignment table) plus a ``smoke()`` reduction of the same
family used by CPU tests. Shapes are the four assigned input-shape cells.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.quantizers import QuantSpec

__all__ = ["ModelConfig", "ShapeConfig", "RunConfig", "SHAPES", "smoke"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 128
    act: str = "silu"            # silu | gelu (gated MLPs) | relu2 (squared ReLU)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1           # every k-th layer is MoE (1 = all layers)
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # SSM (mamba1/mamba2)
    ssm_state: int = 0
    d_inner: int = 0
    conv_width: int = 4
    dt_rank: int = 0
    ssm_head_dim: int = 64       # mamba2 head dim
    ssm_chunk: int = 128         # mamba2 SSD chunk length
    # hybrid (zamba2): one shared attention block applied every k ssm layers
    attn_every: int = 0
    # enc-dec (whisper)
    n_enc_layers: int = 0
    frontend: str = "none"       # none | stub_audio | stub_vision
    # misc
    qk_norm: bool = False
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    vocab_pad_multiple: int = 256

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def attn_dims_ok_message(self) -> str:
        return ""

    def param_count(self) -> int:
        """Analytic total parameter count (for 6ND model-flops)."""
        d, L, V = self.d_model, self.n_layers, self.padded_vocab
        n = V * d  # embed
        if not self.tie_embeddings:
            n += V * d
        if self.family in ("dense", "moe", "encdec"):
            attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head \
                + self.n_heads * self.d_head * d
            if self.act in ("silu", "gelu"):
                mlp_dense = 3 * d * self.d_ff
            else:
                mlp_dense = 2 * d * self.d_ff
            if self.family == "moe":
                n_moe = L // self.moe_every
                n_dense = L - n_moe
                mlp = n_dense * mlp_dense + n_moe * (
                    self.n_experts * mlp_dense + d * self.n_experts
                    + self.n_shared_experts * mlp_dense)
                n += L * attn + mlp
            else:
                layers = L + self.n_enc_layers
                n += layers * (attn + mlp_dense)
                if self.family == "encdec":
                    n += L * attn  # decoder cross-attention
            n += L * 2 * d
        elif self.family in ("ssm", "hybrid"):
            di, ds = self.d_inner, self.ssm_state
            mamba = 2 * d * di + di * self.conv_width + di * (self.dt_rank + 2 * ds) \
                + self.dt_rank * di + di * ds + di + di * d
            n += L * mamba + L * d
            if self.family == "hybrid" and self.attn_every:
                attn = d * self.n_heads * self.d_head + 2 * d * self.n_kv_heads * self.d_head \
                    + self.n_heads * self.d_head * d + 3 * d * self.d_ff
                n += attn  # ONE shared block (zamba2)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.n_layers
        if self.act in ("silu", "gelu"):
            mlp_dense = 3 * d * self.d_ff
        else:
            mlp_dense = 2 * d * self.d_ff
        n_moe = L // self.moe_every
        inactive = n_moe * (self.n_experts - self.top_k - self.n_shared_experts) * mlp_dense
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """Everything the launcher needs besides the model itself."""
    quant: QuantSpec = QuantSpec(kind="bf16")      # serving weight format
    weight_dtype: str = "bf16"                      # training param compute dtype
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    microbatch: int = 0                             # 0 = no grad accumulation
    remat: str = "block"                            # none | block
    kv_cache_dtype: str = "bf16"                    # bf16 | int8
    opt_state_quant: str = "none"                   # none | posit8 (beyond-paper)
    grad_compression: str = "none"                  # none | posit8 (cross-pod)
    zero_shard: bool = True                         # shard opt state over data
    sequence_parallel: bool = False                 # Megatron-SP residuals
    serve_bf16_compute: bool = False                # bf16 q/p in decode attn
    #   (TPU-native mixed dot; CPU runtime can't execute bf16xbf16 thunks,
    #    so smoke tests keep f32 and the dry-run opts in)
    activation_dtype: str = "bf16"
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024
    seed: int = 0


def smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    return dataclasses.replace(
        cfg,
        n_layers=min(cfg.n_layers, 2 if cfg.family != "hybrid" else 3),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 4),
        top_k=min(cfg.top_k, 2),
        n_enc_layers=min(cfg.n_enc_layers, 2),
        d_inner=256 if cfg.d_inner else 0,
        dt_rank=8 if cfg.dt_rank else 0,
        ssm_state=min(cfg.ssm_state, 16),
        ssm_head_dim=32 if cfg.ssm_state else 64,
        ssm_chunk=16,
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
        vocab_pad_multiple=64,
        rope_theta=10000.0,
    )

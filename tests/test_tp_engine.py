"""Tensor-parallel serving engine differential tests (DESIGN.md §9).

The contract: an engine over a 1-D ("tp",) mesh — attention heads / MLP
hidden / experts and the KV cache's head axis sharded, one psum per block
inside shard_map — serves token streams IDENTICAL to the single-device
engine, greedy and sampled, with the Pallas kernels on and off, including
an evict -> resume cycle under a lossy quantized KV cache.

The in-process tests need >= 4 devices: CI runs them in the multi-device
job (XLA_FLAGS=--xla_force_host_platform_device_count=4); on a single
device they skip, and ``test_tp_subprocess_smoke`` still proves the tp=2
differential end to end from the tier-1 suite by forcing fake devices in a
child process.

The differential configs pin ``activation_dtype="f32"``: splitting a
contraction over devices reorders the floating-point accumulation, and at
bf16 the per-matmul rounding makes TP numerically *variant* (a handful of
activations per step land on the far side of a bf16 rounding boundary, and
one flipped cache write compounds into occasional token flips). At f32 the
reordering noise is ~1e-7 relative against O(1) logit gaps, so greedy
argmax and the per-slot sample streams are stable — that is the precision
at which token-identity is a meaningful hardware-independent contract.
"""
import os
import subprocess
import sys

import pytest

from differential import (assert_token_identical, differential_engines,
                          make_engine, make_request)


def _fxp8():
    from repro.core.quantizers import QuantSpec
    return QuantSpec(kind="fxp", M=8, F=7)


def _rcfg():
    from repro.configs import RunConfig
    return RunConfig(remat="none", activation_dtype="f32")


@pytest.fixture(scope="module")
def jax4():
    import jax
    if jax.device_count() < 4:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count"
                    "=4 (CI multi-device job; tier-1 coverage comes from "
                    "test_tp_subprocess_smoke)")
    return jax


# (arch, cfg_overrides): dense GQA, dense MHA (every stock dense smoke is
# GQA with 2 kv groups, so tp=4 head sharding needs the MHA variant), MoE
# with shared experts, and the zamba2 hybrid (replicated mamba blocks +
# the one shared attention block sharded).
ARCH_CASES = {
    "dense": ("yi-9b", None),
    "dense-mha": ("yi-9b", {"n_kv_heads": 4, "n_heads": 4}),
    "moe": ("moonshot-v1-16b-a3b", None),
    "hybrid": ("zamba2-1.2b", None),
}


def _build(tiny, name, tp, *, quant=None, **build_kw):
    """(model, params) for one ARCH_CASES entry on a tp-device mesh."""
    from repro.launch.mesh import make_tp_mesh
    from repro.nn.models import apply_policy

    arch, over = ARCH_CASES[name]
    mesh = make_tp_mesh(tp) if tp > 1 else None
    cfg, model, params = tiny(arch, cfg_overrides=over, rcfg=_rcfg(),
                              mesh=mesh, **build_kw)
    if quant is not None:
        params = apply_policy(params, quant)
    return cfg, model, params


def _reqs(vocab, n=3, max_new=5, **kw):
    return [make_request(i, vocab, max_new=max_new, arrival=float(i), **kw)
            for i in range(n)]


# ---------------------------------------------------------------------------
# The acceptance matrix: tp in {2, 4} x kernels on/off x dense + non-dense
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,tp,use_kernel", [
    ("dense", 2, False),
    ("dense", 2, True),
    ("dense-mha", 4, False),
    ("dense-mha", 4, True),
    ("moe", 2, True),
    ("moe", 4, False),
    ("hybrid", 2, False),
    ("hybrid", 4, True),
])
def test_tp_greedy_token_identical(jax4, tiny, name, tp, use_kernel):
    """Greedy decode is token-identical between tp=1 and tp in {2, 4},
    with the fused Pallas kernels on (pofx8-quantized weights, so the
    matmul kernels actually engage) and off."""
    quant = "pofx8" if use_kernel else None
    cfg, model1, params = _build(tiny, name, 1, quant=quant,
                                 use_kernel=use_kernel)
    _, modelN, _ = _build(tiny, name, tp, quant=quant,
                          use_kernel=use_kernel)
    differential_engines(
        oracle=lambda: make_engine(model1, params, max_len=32),
        variants={f"tp={tp}": lambda: make_engine(modelN, params,
                                                  max_len=32)},
        requests=lambda: _reqs(cfg.vocab_size))


def test_tp_sampled_streams_identical(jax4, tiny):
    """Per-slot temperature/top-k sample streams survive TP: the sampler
    runs replicated on psum'd logits, and slot keys fold absolute
    positions on every device alike."""
    cfg, model1, params = _build(tiny, "hybrid", 1)
    _, model4, _ = _build(tiny, "hybrid", 4)
    differential_engines(
        oracle=lambda: make_engine(model1, params, max_len=32),
        variants={"tp=4": lambda: make_engine(model4, params, max_len=32)},
        requests=lambda: _reqs(cfg.vocab_size, max_new=6, temp=0.7,
                               top_k=8))


# ---------------------------------------------------------------------------
# Evict -> resume under a lossy quantized cache, tensor-parallel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tp", [2, 4])
def test_tp_kv_quant_evict_resume_bit_identity(jax4, tiny, tp):
    """The PR 3 resume guarantee survives sharding: with fxp8 KV codes and
    static scales split along the head axis, an evicted request re-prefills
    to the identical code shards on every device, and the resumed stream
    matches the UNINTERRUPTED single-device run bit for bit."""
    name = "moe" if tp == 2 else "dense-mha"
    cfg, model1, params = _build(tiny, name, 1, kv_spec=_fxp8())
    _, modelN, _ = _build(tiny, name, tp, kv_spec=_fxp8())

    def drive_with_eviction(eng, reqs):
        for r in reqs:
            eng.submit(r)
        eng.admit_ready()
        eng.step()
        eng.evict(eng.active_rids[0])
        while eng.pending_rids or eng.active_rids:
            eng.admit_ready()
            eng.step()
        return {rid: st.out for rid, st in eng._states.items()}

    reqs = lambda: _reqs(cfg.vocab_size, max_new=7, temp=0.7, top_k=8,
                         n=3)
    ref = {s.req.rid: s.out
           for s in make_engine(model1, params).run(reqs())}
    got = drive_with_eviction(make_engine(modelN, params), reqs())
    assert_token_identical(got, ref, label=f"tp={tp}+evict",
                           oracle_label="tp=1 uninterrupted")


# ---------------------------------------------------------------------------
# Sharding-validity guards (no mesh / few devices needed)
# ---------------------------------------------------------------------------


def test_tp_rejects_indivisible_heads(jax4, tiny):
    """A GQA arch whose kv groups don't divide tp must fail loudly at
    engine construction (silent replication would break the manual psum
    contract), naming the offending leaf."""
    with pytest.raises(ValueError, match="does not divide dim 'kv_heads'"):
        _, model, params = _build(tiny, "dense", 4)   # smoke yi-9b: G=2
        make_engine(model, params)


def test_param_specs_shard_codes_and_scales(jax4, tiny):
    """QuantizedTensor leaves shard codes AND scales consistently: the
    attention head axis shards with a broadcast (size-1) scale dim, the
    MLP hidden axis shards its per-channel scale alongside the codes."""
    _, model, params = _build(tiny, "dense", 2, quant="pofx8")
    specs = model.param_tp_specs(params)
    wq = specs["blocks"]["attn"]["wq"]        # codes (L, d, H, Dh)
    assert tuple(wq.codes) == (None, None, "tp", None)
    assert all(a is None for a in tuple(wq.scale))
    wg = specs["blocks"]["mlp"]["wg"]         # codes (L, d, ff)
    assert tuple(wg.codes) == (None, None, "tp")
    assert tuple(wg.scale) == (None, None, "tp")   # (L, 1, ff) per-channel
    wo = specs["blocks"]["mlp"]["wo"]         # codes (L, ff, d): row shard
    assert tuple(wo.codes) == (None, "tp", None)
    assert all(a is None for a in tuple(wo.scale))


def test_validate_scale_sharding_congruence():
    """core.policy.validate_scale_sharding: broadcast scales replicate,
    per-channel scales shard with their codes, incongruent layouts raise."""
    from jax.sharding import PartitionSpec as P

    from repro.core.policy import validate_scale_sharding

    # per-tensor / broadcast scale over a sharded axis -> replicated
    s = validate_scale_sharding("w", (64, 128), (1, 1), P(None, "tp"))
    assert tuple(s) == (None, None)
    # per-channel scale along the sharded axis -> shards with the codes
    s = validate_scale_sharding("w", (64, 128), (1, 128), P(None, "tp"))
    assert tuple(s) == (None, "tp")
    # lower-rank scale aligns like numpy broadcasting (trailing dims)
    s = validate_scale_sharding("w", (64, 128), (128,), P(None, "tp"))
    assert tuple(s) == ("tp",)
    # a scale varying along the sharded axis at a different granularity
    # cannot be split consistently with its codes
    with pytest.raises(ValueError, match="must match the sharded axis"):
        validate_scale_sharding("w", (64, 128), (1, 32), P(None, "tp"))
    with pytest.raises(ValueError, match="scale rank"):
        validate_scale_sharding("w", (64,), (2, 64), P("tp"))


def test_cache_specs_shard_head_axis(jax4, tiny):
    """KV cache codes and static scales shard along the head axis; pos and
    SSM state replicate (slot logic is device-count-agnostic)."""
    _, model, _ = _build(tiny, "hybrid", 2, kv_spec=_fxp8())
    cache = model.init_cache(2, 16)
    import jax.numpy as jnp
    cache["pos"] = jnp.zeros((2,), jnp.int32)
    specs = model.cache_tp_specs(cache)
    kv = specs["shared_kv"]
    assert tuple(kv["k"]) == (None, None, "tp", None, None)
    assert tuple(kv["k_scale"]) == (None, None, "tp", None, None)
    assert all(a is None for a in tuple(specs["ssm"]["ssm"]))
    assert tuple(specs["pos"]) in ((), (None,))


# ---------------------------------------------------------------------------
# Tier-1 coverage on a single device: the tp=2 differential in a child
# process with forced fake devices (the pattern test_sharding_dryrun uses)
# ---------------------------------------------------------------------------


def test_tp_subprocess_smoke():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys; sys.path.insert(0, "src")
import numpy as np, jax
from repro.configs import ARCHS, RunConfig, smoke
from repro.launch.engine import Request, SamplingParams, ServeEngine
from repro.launch.mesh import make_tp_mesh
from repro.nn.models import build_model

cfg = smoke(ARCHS["yi-9b"])
rcfg = RunConfig(remat="none", activation_dtype="f32")
params = build_model(cfg, rcfg).init(jax.random.PRNGKey(0))
def reqs():
    return [Request(rid=i,
                    prompt=np.random.RandomState(i).randint(0, cfg.vocab_size, 8),
                    max_new=4, sampling=SamplingParams(), arrival=float(i))
            for i in range(3)]
outs = {}
for tp in (1, 2):
    mesh = make_tp_mesh(tp) if tp > 1 else None
    eng = ServeEngine(build_model(cfg, rcfg, mesh=mesh), params,
                      n_slots=2, max_len=24, chunk=3)
    outs[tp] = {s.req.rid: s.out for s in eng.run(reqs())}
assert outs[1] == outs[2], (outs[1], outs[2])
print("OK tp-differential")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=1200)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK tp-differential" in r.stdout

"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1 + 1 shared expert, interleaved
(every other layer MoE) — early fusion [hf:meta-llama/Llama-4]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab_size=202048, act="silu",
    n_experts=128, top_k=1, moe_every=2, n_shared_experts=1,
    rope_theta=500000.0,
)

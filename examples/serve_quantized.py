"""Serving example: batched generation with PoFx-stored weights.

Wraps repro.launch.serve: loads/initializes a model, quantizes the weights
to the paper's normalized-posit format, prefills a batch of prompts and
decodes greedily with a donated KV cache, reporting storage + throughput.

    PYTHONPATH=src python examples/serve_quantized.py --arch moonshot-v1-16b-a3b
"""
import argparse

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--quant", default="pofx8")
    args = ap.parse_args()
    serve_main(["--arch", args.arch, "--smoke", "--quant", args.quant,
                "--batch", "4", "--prompt-len", "48", "--gen", "16"])

"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel in this package must match its oracle bit-exactly (integer
decode paths) or to float tolerance (accumulating matmuls) across the shape/
dtype sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.pofx import pofx_normalized

__all__ = ["pofx_decode_ref", "pofx_matmul_ref", "fxp_matmul_ref", "decode_norm_to_fxp"]


def decode_norm_to_fxp(codes, N: int, ES: int, M: int):
    """Normalized posit codes -> FxP(M, M-1) two's-complement int32.

    This is the elementwise function both the oracle and the kernels share:
    bit-level Algorithm 1 (stages A-E), jnp ops only, Pallas-safe.
    """
    out, _ = pofx_normalized(codes, N, ES, M)
    return out


def pofx_decode_ref(codes, N: int, ES: int, M: int = 8) -> jax.Array:
    """Oracle for the decode kernel: uint8 codes -> int8 FxP codes."""
    return decode_norm_to_fxp(codes.astype(jnp.int32), N, ES, M).astype(jnp.int8)


def pofx_matmul_ref(x, codes, scale, N: int, ES: int, M: int = 8) -> jax.Array:
    """Oracle for the fused Move&Store kernel.

    x: (m, k) float; codes: (k, n) normalized posit; scale: (1, n) or (n,)
    per-output-channel normalizer. Result fp32: x @ (decode(codes)/2^(M-1)) * scale.
    """
    fxp = decode_norm_to_fxp(codes.astype(jnp.int32), N, ES, M)
    w = fxp.astype(jnp.float32) * (1.0 / (1 << (M - 1)))
    y = jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)
    return y * jnp.reshape(scale, (1, -1)).astype(jnp.float32)


def fxp_matmul_ref(a, b) -> jax.Array:
    """Oracle for the FxP MAC kernel: int8 x int8 -> int32 accumulate.

    The int32 accumulator is the TPU analogue of the paper's 3M-bit adder
    (M=8 -> 24 bits of headroom needed; int32 provides 32).
    """
    return jnp.dot(a.astype(jnp.int32), b.astype(jnp.int32),
                   preferred_element_type=jnp.int32)

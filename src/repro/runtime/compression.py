"""Posit-compressed cross-pod gradient reduction (beyond-paper).

The paper compresses *stored/communicated weights* with normalized posits.
Here the same codec compresses the slowest collective in multi-pod training:
the cross-pod (DCN) gradient all-reduce. Each pod

  1. (optionally) adds its error-feedback residual,
  2. scales by a per-tensor power-of-two normalizer,
  3. encodes to (N-1)-bit normalized posit codes (uint8 on the wire),
  4. all-gathers CODES over the ``pod`` axis — (N-1)/32 of the fp32 bytes,
     (N-1)/16 of bf16 — then decodes and means locally.

Integration: the per-pod gradients come from a ``jax.shard_map`` whose
manual axis set is {"pod"} — GSPMD still auto-partitions data/model inside
— so the pod reduction is literally ours to implement (launch/train.py).

Error feedback keeps the quantization *bias* out of SGD: the residual
(g - decode(encode(g))) is added to the next step's gradient, making the
compressed estimator unbiased over time (standard EF-SGD argument).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.normalized_posit import norm_decode, norm_encode_arith

__all__ = ["posit_compressed_mean", "compressed_grad_transform"]


def _pow2_scale(x: jax.Array) -> jax.Array:
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30)
    return jnp.exp2(jnp.ceil(jnp.log2(amax))).astype(jnp.float32)


def posit_compressed_mean(x: jax.Array, axis_name: str, *, N: int = 8,
                          ES: int = 2,
                          residual: Optional[jax.Array] = None
                          ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Mean of ``x`` over a *manual* mesh axis with posit-coded transport.

    Must be called inside shard_map with ``axis_name`` manual. Returns
    (mean, new_residual); new_residual is None iff residual is None.
    """
    xf = x.astype(jnp.float32)
    if residual is not None:
        xf = xf + residual
    scale = _pow2_scale(xf)
    codes = norm_encode_arith(xf / scale, N, ES).astype(jnp.uint8)
    if residual is not None:
        local_decoded = norm_decode(codes.astype(jnp.int32), N, ES) * scale
        new_residual = xf - local_decoded
    else:
        new_residual = None
    # uint8 codes + one f32 scalar cross the DCN instead of f32 tensors.
    g_codes = jax.lax.all_gather(codes, axis_name)            # (P, ...)
    g_scale = jax.lax.all_gather(scale, axis_name)            # (P,)
    vals = norm_decode(g_codes.astype(jnp.int32), N, ES)
    shape = (-1,) + (1,) * (vals.ndim - 1)
    mean = jnp.mean(vals * g_scale.reshape(shape), axis=0)
    return mean.astype(x.dtype), new_residual


def compressed_grad_transform(grads, axis_name: str, *, N: int = 8, ES: int = 2,
                              residuals=None):
    """Tree-mapped posit_compressed_mean. residuals: matching tree or None."""
    if residuals is None:
        out = jax.tree.map(
            lambda g: posit_compressed_mean(g, axis_name, N=N, ES=ES)[0], grads)
        return out, None
    pairs = jax.tree.map(
        lambda g, r: posit_compressed_mean(g, axis_name, N=N, ES=ES, residual=r),
        grads, residuals)
    means = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return means, res

"""Checkpointing: atomic, async, keep-k, posit-compressed, elastic.

Layout (one directory per step, atomically renamed into place):

    <dir>/step_00000420/
        manifest.json      step, leaf count, shapes/dtypes, compression info
        treedef.pkl        pytree structure (includes QuantSpec statics)
        leaf_00000.npy ... one file per pytree leaf (raw or posit-packed)

Fault-tolerance contract:
  * atomicity — writes land in ``<dir>/.tmp_<step>`` and are renamed only
    after every file is fsynced; a crash mid-save never corrupts the latest
    valid checkpoint (restore scans for the newest complete manifest).
  * async — ``save`` snapshots to host memory synchronously (the step can
    proceed) and does disk I/O on a background thread; ``wait()`` joins.
  * keep-k GC — older step dirs are deleted after a successful save.
  * elastic restore — leaves are stored unsharded; ``restore`` device_puts
    onto whatever sharding tree the *current* mesh dictates, so a relaunch
    on a different pod/slice count resumes seamlessly.
  * posit compression (the paper's storage claim applied to checkpoints) —
    float leaves under the top-level ``params`` key are stored as
    bit-packed normalized Posit(N-1,ES) codes + per-channel scale when a
    QuantSpec is supplied: 7 bits/weight vs 32 (fp32) is a 4.6x smaller
    checkpoint, the Table-6 storage row at rest.
  * quantized-tensor round-trip — ``QuantizedTensor`` leaves (post-training
    quantized params, see repro.core.policy) are first-class: codes are
    bit-packed at their stored width, the spec is recorded in the manifest
    as its canonical grammar string, and ``restore`` rebuilds identical
    QuantizedTensor objects. ``save(..., policy=...)`` additionally records
    the QuantPolicy string so a serving relaunch can recover it via
    ``read_manifest``.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pickle
import shutil
import threading
from typing import Any, Dict, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.normalized_posit import (norm_decode_np, norm_encode_np,
                                         pack_bits, unpack_bits)
from repro.core.policy import QuantPolicy, format_spec, parse_spec
from repro.core.quantizers import QuantSpec, QuantizedTensor

__all__ = ["CheckpointManager"]


def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype string, including ml_dtypes names (bfloat16 etc.)."""
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _reinterpret(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    """np.save round-trips ml_dtypes arrays as void bytes; view them back."""
    want = _np_dtype(dtype_name)
    if arr.dtype != want and arr.dtype.kind == "V":
        return arr.view(want)
    return arr


def _is_param_path(path) -> bool:
    first = path[0]
    key = getattr(first, "key", getattr(first, "name", None))
    return key == "params"


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save -----------------------------------------------------------------

    def save(self, step: int, state: Any,
             param_compress: Optional[QuantSpec] = None,
             policy: Optional[Union[QuantPolicy, str]] = None) -> None:
        self.wait()
        flat, treedef = jax.tree_util.tree_flatten_with_path(
            state, is_leaf=lambda x: isinstance(x, QuantizedTensor))
        host_leaves = []
        for path, leaf in flat:
            if isinstance(leaf, QuantizedTensor):
                host_leaves.append(QuantizedTensor(
                    np.asarray(jax.device_get(leaf.codes)),
                    np.asarray(jax.device_get(leaf.scale)), leaf.spec))
                continue
            arr = np.asarray(jax.device_get(leaf))
            compress = (param_compress is not None and _is_param_path(path)
                        and np.issubdtype(arr.dtype, np.floating)
                        and arr.ndim >= 2)
            host_leaves.append((arr, compress))
        policy_s = (policy.to_string() if isinstance(policy, QuantPolicy)
                    else policy)
        payload = (step, treedef, host_leaves, param_compress, policy_s)
        if self.async_save:
            self._thread = threading.Thread(target=self._write, args=payload)
            self._thread.start()
        else:
            self._write(*payload)

    def _write(self, step, treedef, host_leaves, spec, policy_s=None) -> None:
        tmp = os.path.join(self.dir, f".tmp_{step:08d}")
        final = os.path.join(self.dir, f"step_{step:08d}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest: Dict[str, Any] = {"step": step, "leaves": []}
        if policy_s is not None:
            manifest["quant_policy"] = policy_s
        for i, item in enumerate(host_leaves):
            name = f"leaf_{i:05d}.npy"
            if isinstance(item, QuantizedTensor):
                entry = self._write_quantized(tmp, name, i, item)
                manifest["leaves"].append(entry)
                continue
            arr, compress = item
            entry = {"file": name, "shape": list(arr.shape),
                     "dtype": str(arr.dtype), "compressed": bool(compress)}
            if compress:
                N, ES = spec.N, spec.ES
                scale = np.maximum(np.abs(arr).max(axis=tuple(range(arr.ndim - 1)),
                                                   keepdims=True), 1e-12)
                scale = np.exp2(np.ceil(np.log2(scale))).astype(np.float32)
                codes = norm_encode_np((arr / scale).astype(np.float64), N, ES)
                packed = pack_bits(codes, N - 1)
                np.save(os.path.join(tmp, name), packed)
                np.save(os.path.join(tmp, f"scale_{i:05d}.npy"), scale)
                entry.update(N=N, ES=ES, count=int(arr.size),
                             scale_file=f"scale_{i:05d}.npy")
            else:
                np.save(os.path.join(tmp, name), arr)
            manifest["leaves"].append(entry)
        with open(os.path.join(tmp, "treedef.pkl"), "wb") as f:
            pickle.dump(treedef, f)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._gc()

    @staticmethod
    def _write_quantized(tmp: str, name: str, i: int,
                         qt: QuantizedTensor) -> Dict[str, Any]:
        """One QuantizedTensor leaf: bit-packed codes + scale + spec string."""
        spec = qt.spec
        codes = np.asarray(qt.codes)
        entry: Dict[str, Any] = {
            "file": name, "shape": list(codes.shape),
            "dtype": str(codes.dtype), "qspec": format_spec(spec),
            "count": int(codes.size),
            "scale_file": f"scale_{i:05d}.npy",
        }
        if spec.rounding != "trunc":  # not expressible in the grammar
            entry["rounding"] = spec.rounding
        k = spec.stored_bits
        if spec.kind in ("fp32", "bf16") or k > 16:
            entry["packed"] = False
            np.save(os.path.join(tmp, name), codes)
        else:
            # fxp codes are signed two's complement: mask to k bits before
            # packing and sign-extend on restore.
            entry["packed"] = True
            masked = codes.astype(np.int64) & ((1 << k) - 1)
            np.save(os.path.join(tmp, name), pack_bits(masked, k))
        np.save(os.path.join(tmp, entry["scale_file"]), np.asarray(qt.scale))
        return entry

    @staticmethod
    def _read_quantized(root: str, entry: Dict[str, Any]) -> QuantizedTensor:
        spec = parse_spec(entry["qspec"])
        if "rounding" in entry:
            spec = dataclasses.replace(spec, rounding=entry["rounding"])
        raw = np.load(os.path.join(root, entry["file"]))
        dtype = _np_dtype(entry["dtype"])
        if entry.get("packed"):
            k = spec.stored_bits
            codes = unpack_bits(raw, k, entry["count"]).astype(np.int64)
            if spec.kind == "fxp":  # sign-extend k-bit two's complement
                codes = codes - ((codes >> (k - 1)) << k)
            codes = codes.astype(dtype).reshape(entry["shape"])
        else:
            codes = _reinterpret(raw, entry["dtype"]).reshape(entry["shape"])
        scale = np.load(os.path.join(root, entry["scale_file"]))
        return QuantizedTensor(codes, scale, spec)

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore ----------------------------------------------------------------

    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, "manifest.json")):
                out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def read_manifest(self, step: Optional[int] = None) -> Dict[str, Any]:
        """Checkpoint metadata (incl. "quant_policy" when saved with one)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        root = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(root, "manifest.json")) as f:
            return json.load(f)

    def restore(self, step: Optional[int] = None, shardings: Any = None) -> Any:
        """Load a checkpoint; device_put onto ``shardings`` (elastic restore).

        shardings: optional pytree (same treedef) of NamedSharding/None.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        root = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(root, "manifest.json")) as f:
            manifest = json.load(f)
        with open(os.path.join(root, "treedef.pkl"), "rb") as f:
            treedef = pickle.load(f)
        leaves = []
        for entry in manifest["leaves"]:
            if "qspec" in entry:
                leaves.append(self._read_quantized(root, entry))
                continue
            raw = np.load(os.path.join(root, entry["file"]))
            if entry.get("compressed"):
                N, ES = entry["N"], entry["ES"]
                codes = unpack_bits(raw, N - 1, entry["count"])
                scale = np.load(os.path.join(root, entry["scale_file"]))
                arr = (norm_decode_np(codes, N, ES).reshape(entry["shape"])
                       * scale).astype(_np_dtype(entry["dtype"]))
            else:
                arr = _reinterpret(raw, entry["dtype"])
            leaves.append(arr)
        state = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            flat_s, treedef_s = jax.tree_util.tree_flatten(
                shardings, is_leaf=lambda x: x is None)
            flat_x = treedef_s.flatten_up_to(state)
            state = treedef_s.unflatten([
                jax.device_put(x, s) if s is not None else jnp.asarray(x)
                for x, s in zip(flat_x, flat_s)])
        else:
            state = jax.tree.map(jnp.asarray, state)
        return state

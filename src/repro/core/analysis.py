"""Behavioral analysis — the paper's Fig. 8 multi-level error pipeline.

Level (a): per-layer weight quantization error  -> prune bad configs early
Level (b): per-layer output-activation error with quantized weights
Level (c): end-to-end task metric of the quantized network

plus the joint Pareto analysis over (error, storage, decode-cost) that
produces Tables 3/4.  Model-agnostic: works on any pytree of weights and any
apply-fn; examples/behavioral_analysis.py drives it end-to-end.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .quantizers import QuantSpec, QuantizedTensor, dequantize, quantize, storage_bits

__all__ = [
    "weight_error",
    "activation_error",
    "sweep_configs",
    "BehavioralReport",
    "default_spec_grid",
]


def weight_error(w, spec: QuantSpec, axis: Optional[int] = None) -> Dict[str, float]:
    """Quantization-induced error stats of one weight tensor (paper Fig. 16).

    avg_rel: average absolute relative error (paper's headline metric),
    max_abs: maximum absolute error; mse for completeness.
    """
    w = jnp.asarray(w, jnp.float32)
    qt = quantize(w, spec, axis=axis)
    wq = dequantize(qt, jnp.float32)
    err = jnp.abs(wq - w)
    denom = jnp.maximum(jnp.abs(w), 1e-8)
    return {
        "avg_rel": float(jnp.mean(err / denom)),
        "avg_abs": float(jnp.mean(err)),
        "max_abs": float(jnp.max(err)),
        "mse": float(jnp.mean(err**2)),
        "bits": storage_bits(qt),
    }


def activation_error(apply_fn: Callable, w, spec: QuantSpec, x,
                     axis: Optional[int] = None) -> Dict[str, float]:
    """Error in a layer's outputs when its weights are quantized (level b)."""
    y_ref = apply_fn(jnp.asarray(w, jnp.float32), x)
    wq = dequantize(quantize(w, spec, axis=axis), jnp.float32)
    y_q = apply_fn(wq, x)
    err = jnp.abs(y_q - y_ref)
    denom = jnp.maximum(jnp.abs(y_ref), 1e-6)
    return {
        "avg_rel": float(jnp.mean(err / denom)),
        "avg_abs": float(jnp.mean(err)),
        "max_abs": float(jnp.max(err)),
    }


@dataclasses.dataclass
class BehavioralReport:
    per_config: Dict[str, Dict]            # spec name -> level a/b/c results
    pruned_at_a: List[str]
    pruned_at_b: List[str]
    survivors: List[str]

    def table(self) -> str:
        rows = ["config,avg_rel_weight_err,act_err,metric,bits_per_weight,pruned"]
        for name, r in sorted(self.per_config.items()):
            rows.append(
                f"{name},{r.get('weight_avg_rel', float('nan')):.5f},"
                f"{r.get('act_avg_rel', float('nan')):.5f},"
                f"{r.get('metric', float('nan')):.4f},"
                f"{r.get('bits_per_weight', float('nan')):.2f},"
                f"{r.get('pruned', '')}"
            )
        return "\n".join(rows)


def spec_name(spec: QuantSpec) -> str:
    if spec.kind in ("fp32", "bf16"):
        return spec.kind
    if spec.kind == "fxp":
        return f"fxp{spec.M}"
    if spec.kind == "posit":
        return f"posit({spec.N},{spec.ES})"
    return f"pofx({spec.N - 1},{spec.ES},{spec.path})"


def default_spec_grid(include_paths: bool = True) -> List[QuantSpec]:
    """The paper's sweep: FxP{7,8,16}, Posit(N in 5..8, ES in 0..3), PoFx."""
    specs: List[QuantSpec] = [QuantSpec(kind="fxp", M=7, F=6),
                              QuantSpec(kind="fxp", M=8, F=7),
                              QuantSpec(kind="fxp", M=16, F=15)]
    for N in (5, 6, 7, 8):
        for ES in (0, 1, 2, 3):
            specs.append(QuantSpec(kind="posit", N=N, ES=ES))
    for N in (6, 7, 8):
        for ES in (1, 2, 3):
            specs.append(QuantSpec(kind="pofx", N=N, ES=ES, path="via_fxp"))
            if include_paths:
                specs.append(QuantSpec(kind="pofx", N=N, ES=ES, path="direct"))
    return specs


def sweep_configs(
    weights: Dict[str, jax.Array],
    specs: Sequence[QuantSpec],
    *,
    layer_apply: Optional[Dict[str, Tuple[Callable, jax.Array]]] = None,
    end_to_end: Optional[Callable[[QuantSpec], float]] = None,
    prune_weight_err: float = 0.5,
    prune_act_err: float = 0.5,
) -> BehavioralReport:
    """Run the three-level Fig. 8 pipeline over a spec grid.

    weights: named weight tensors (level a averages over them).
    layer_apply: name -> (apply_fn, sample_input) for level b.
    end_to_end: spec -> task metric (higher is better) for level c; only
    called for configs surviving levels a and b (the paper's early pruning).
    """
    per_config: Dict[str, Dict] = {}
    pruned_a, pruned_b, survivors = [], [], []
    for spec in specs:
        name = spec_name(spec)
        rec: Dict = {}
        errs = [weight_error(w, spec, axis=-1) for w in weights.values()]
        rec["weight_avg_rel"] = float(np.mean([e["avg_rel"] for e in errs]))
        rec["weight_max_abs"] = float(np.max([e["max_abs"] for e in errs]))
        total_bits = sum(e["bits"] for e in errs)
        total_n = sum(int(np.prod(w.shape)) for w in weights.values())
        rec["bits_per_weight"] = total_bits / max(total_n, 1)
        if rec["weight_avg_rel"] > prune_weight_err:
            rec["pruned"] = "level_a"
            pruned_a.append(name)
            per_config[name] = rec
            continue
        if layer_apply:
            act = [activation_error(fn, weights[k], spec, x)
                   for k, (fn, x) in layer_apply.items() if k in weights]
            rec["act_avg_rel"] = float(np.mean([a["avg_rel"] for a in act])) if act else 0.0
            if rec["act_avg_rel"] > prune_act_err:
                rec["pruned"] = "level_b"
                pruned_b.append(name)
                per_config[name] = rec
                continue
        if end_to_end is not None:
            rec["metric"] = float(end_to_end(spec))
        survivors.append(name)
        per_config[name] = rec
    return BehavioralReport(per_config, pruned_a, pruned_b, survivors)

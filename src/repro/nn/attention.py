"""GQA attention: chunked (flash-style) causal/bidirectional attention for
train/prefill, cache-based decode, TP mode selection with divisibility-aware
fallbacks, rotary embeddings, optional qk-norm (chameleon).

TP modes (model axis = tp):
  kv    — kv heads divide tp: shard kv-head group axis (no extra collectives)
  rep   — q-heads-per-group divide tp: shard the rep axis; k/v replicated
  dim   — fallback: shard head_dim (contracting): GSPMD inserts psum partials
The mode is picked per architecture (see DESIGN.md §4); llama3's 8 kv groups
use ``rep`` (128/8 = 16 q-heads per group), llama4's 40 heads use ``dim``.

Decode uses a sequence-sharded KV cache ("kv_seq" -> model): GSPMD partitions
the softmax reduction into per-chip partial max/sum + tiny all-reduces — the
flash-decoding pattern — so a 500k-token cache never moves.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantizers import kv_dequantize, kv_quantize
from .layers import (Param, apply_rotary, dense_init, matmul_param,
                     param_value, rmsnorm, rotary_cos_sin)

NEG_INF = -1e30


def attn_tp_mode(n_heads: int, n_kv_heads: int, tp: int) -> str:
    """TP strategy for (H, G, tp):

    kv      G % tp == 0: shard the kv-head axis (zero redundancy)
    rep     R % tp == 0: shard the rep axis, replicate k/v per group
    expand  H % tp == 0: repeat k/v to H heads and shard the full head
            axis (Megatron GQA fallback — kv memory/compute replicates
            R/tp-fold but q-side compute shards exactly; without this the
            partitioner replicates the whole attention, 16x the flops —
            EXPERIMENTS.md §Perf iter 1)
    none    nothing divides: replicated attention (documented fallback)
    """
    if tp <= 1:
        return "kv"
    if n_kv_heads % tp == 0:
        return "kv"
    if n_kv_heads and (n_heads // n_kv_heads) % tp == 0:
        return "rep"
    if n_heads % tp == 0:
        return "expand"
    return "none"


def _q_logical(mode: str):
    # q laid out (B, S, G, rep, Dh); expand mode rewrites to (B, S, H, 1, Dh)
    if mode in ("kv", "expand"):
        return ("batch", "seq_attn", "kv_heads", None, "head_dim")
    if mode == "rep":
        return ("batch", "seq_attn", None, "heads", "head_dim")
    return ("batch", "seq_attn", None, None, None)


def _kv_logical(mode: str):
    # k/v laid out (B, S, G, Dh); expand mode repeats to (B, S, H, Dh)
    if mode in ("kv", "expand"):
        return ("batch", "seq_attn", "kv_heads", "head_dim")
    if mode == "rep":
        return ("batch", "seq_attn", None, "head_dim")
    return ("batch", "seq_attn", None, None)


def attn_init(key, cfg, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 6)
    d, H, G, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    p = {
        "wq": dense_init(ks[0], d, (H, Dh), dtype=dtype),
        "wk": dense_init(ks[1], d, (G, Dh), dtype=dtype),
        "wv": dense_init(ks[2], d, (G, Dh), dtype=dtype),
        "wo": dense_init(ks[3], H * Dh, d, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((Dh,), dtype)
        p["k_norm"] = jnp.ones((Dh,), dtype)
    return p


def attn_logical(cfg) -> dict:
    p = {
        "wq": ("p_embed", "heads", "head_dim"),
        "wk": ("p_embed", "kv_heads", "head_dim"),
        "wv": ("p_embed", "kv_heads", "head_dim"),
        "wo": ("mlp", "p_embed"),  # (H*Dh, d): row dim always tp-divisible
    }
    if cfg.qk_norm:
        p["q_norm"] = ("p_unsharded",)
        p["k_norm"] = ("p_unsharded",)
    return p


def _maybe_expand(q, k, v, mode: str, H: int, R: int):
    """expand mode: repeat k/v to the full head count and flatten q's
    (G, R) to (H, 1) so the head axis shards exactly over the model axis."""
    if mode != "expand":
        return q, k, v
    B, Sq = q.shape[:2]
    Dh = q.shape[-1]
    return (q.reshape(B, Sq, H, 1, Dh),
            jnp.repeat(k, R, axis=2), jnp.repeat(v, R, axis=2))


def _divisor_chunk(total: int, want: int) -> int:
    want = max(1, min(want, total))
    for c in range(want, 0, -1):
        if total % c == 0:
            return c
    return 1


def _blk_scores(q_blk, k_blk, scale, causal, qi, kvc, bias_offset, n_kv_full,
                kj):
    """(masked) attention scores for one (q-chunk, kv-block) pair, f32."""
    s = jnp.einsum("bqgrd,bkgd->bgrqk", q_blk, k_blk,
                   preferred_element_type=jnp.float32) * scale
    if causal:
        qc = q_blk.shape[1]
        qpos = bias_offset + qi + jnp.arange(qc)
        kpos = kj * kvc + jnp.arange(kvc)
        mask = kpos[None, :] <= qpos[:, None]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    return s


def _flash_fwd(causal, qc, kvc, bias_offset, q, k, v):
    """Online-softmax forward. Returns (out (B,Sq,G,R,Dh), lse (B,G,R,Sq))."""
    B, Sq, G, R, Dh = q.shape
    Skv = k.shape[1]
    scale = Dh ** -0.5
    outs, lses = [], []
    for qi in range(0, Sq, qc):
        q_blk = jax.lax.dynamic_slice_in_dim(q, qi, qc, axis=1)
        q_end = qi + qc + bias_offset
        kv_hi = Skv if not causal else min(Skv, ((q_end + kvc - 1) // kvc) * kvc)
        n_kv = kv_hi // kvc

        def body(carry, kj, q_blk=q_blk, qi=qi):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, kj * kvc, kvc, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, kj * kvc, kvc, axis=1)
            s = _blk_scores(q_blk, k_blk, scale, causal, qi, kvc, bias_offset,
                            n_kv, kj)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bgrqk,bkgd->bgrqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, G, R, qc), NEG_INF, jnp.float32),
                jnp.zeros((B, G, R, qc), jnp.float32),
                jnp.zeros((B, G, R, qc, Dh), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(n_kv))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(jnp.transpose(o, (0, 3, 1, 2, 4)).astype(q.dtype))
        lses.append(m + jnp.log(jnp.maximum(l, 1e-30)))
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    lse = jnp.concatenate(lses, axis=-1) if len(lses) > 1 else lses[0]
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _flash(causal, qc, kvc, bias_offset, q, k, v):
    out, _ = _flash_fwd(causal, qc, kvc, bias_offset, q, k, v)
    return out


def _flash_fwd_rule(causal, qc, kvc, bias_offset, q, k, v):
    out, lse = _flash_fwd(causal, qc, kvc, bias_offset, q, k, v)
    return out, (q, k, v, out, lse)


def _flash_bwd_rule(causal, qc, kvc, bias_offset, res, dout):
    """Flash-attention backward: recompute P per block from (q,k,v,lse).

    This is the memory fix that makes 32k-token training fit HBM: naive
    autodiff through the online-softmax scan saves the (qc, kvc)
    probability blocks and masks for every iteration (terabytes at 32k —
    EXPERIMENTS.md §Perf iter 2); the custom VJP saves only q,k,v,out,lse
    and rebuilds each block in the backward sweep, FLOPs for bytes —
    the same trade the paper's PoFx makes (decode on the fly, store less).
    """
    q, k, v, out, lse = res
    B, Sq, G, R, Dh = q.shape
    Skv = k.shape[1]
    scale = Dh ** -0.5
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                       # (B,Sq,G,R)
    delta = jnp.transpose(delta, (0, 2, 3, 1))     # (B,G,R,Sq)
    dq_chunks = []
    dk = jnp.zeros(k.shape, jnp.float32)
    dv = jnp.zeros(v.shape, jnp.float32)
    for qi in range(0, Sq, qc):
        q_blk = jax.lax.dynamic_slice_in_dim(q, qi, qc, axis=1)
        do_blk = jax.lax.dynamic_slice_in_dim(dout, qi, qc, axis=1)
        lse_blk = jax.lax.dynamic_slice_in_dim(lse, qi, qc, axis=-1)
        dlt_blk = jax.lax.dynamic_slice_in_dim(delta, qi, qc, axis=-1)
        q_end = qi + qc + bias_offset
        kv_hi = Skv if not causal else min(Skv, ((q_end + kvc - 1) // kvc) * kvc)
        n_kv = kv_hi // kvc

        def body(carry, kj, q_blk=q_blk, do_blk=do_blk, lse_blk=lse_blk,
                 dlt_blk=dlt_blk, qi=qi):
            dq_acc, dk_acc, dv_acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, kj * kvc, kvc, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, kj * kvc, kvc, axis=1)
            s = _blk_scores(q_blk, k_blk, scale, causal, qi, kvc, bias_offset,
                            n_kv, kj)
            p = jnp.exp(s - lse_blk[..., None])           # (B,G,R,qc,kvc)
            dv_blk = jnp.einsum("bgrqk,bqgrd->bkgd", p,
                                do_blk.astype(jnp.float32))
            dp = jnp.einsum("bqgrd,bkgd->bgrqk", do_blk, v_blk,
                            preferred_element_type=jnp.float32)
            ds = p * (dp - dlt_blk[..., None]) * scale
            dq_acc = dq_acc + jnp.einsum("bgrqk,bkgd->bqgrd",
                                         ds.astype(k_blk.dtype), k_blk,
                                         preferred_element_type=jnp.float32)
            dk_blk = jnp.einsum("bgrqk,bqgrd->bkgd", ds.astype(q_blk.dtype),
                                q_blk, preferred_element_type=jnp.float32)
            dk_acc = jax.lax.dynamic_update_slice_in_dim(
                dk_acc, jax.lax.dynamic_slice_in_dim(dk_acc, kj * kvc, kvc, 1)
                + dk_blk, kj * kvc, axis=1)
            dv_acc = jax.lax.dynamic_update_slice_in_dim(
                dv_acc, jax.lax.dynamic_slice_in_dim(dv_acc, kj * kvc, kvc, 1)
                + dv_blk, kj * kvc, axis=1)
            return (dq_acc, dk_acc, dv_acc), None

        init = (jnp.zeros((B, qc, G, R, Dh), jnp.float32), dk, dv)
        (dq_blk, dk, dv), _ = jax.lax.scan(body, init, jnp.arange(n_kv))
        dq_chunks.append(dq_blk)
    dq = (jnp.concatenate(dq_chunks, axis=1) if len(dq_chunks) > 1
          else dq_chunks[0])
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_attention(q, k, v, *, causal: bool, q_chunk: int, kv_chunk: int,
                    ctx, mode: str, bias_offset: int = 0) -> jax.Array:
    """Online-softmax chunked attention with a flash (recompute) backward.

    q: (B, Sq, G, R, Dh); k/v: (B, Skv, G, Dh). Python-unrolled q-chunk loop
    so causal q-chunks only visit kv-chunks up to the diagonal (true FLOPs
    savings, static shapes). bias_offset: k positions lead q by this offset
    (prefill against an existing cache prefix).
    """
    Sq, Skv = q.shape[1], k.shape[1]
    qc = _divisor_chunk(Sq, q_chunk)
    kvc = _divisor_chunk(Skv, kv_chunk)
    out = _flash(causal, qc, kvc, bias_offset, q, k, v)
    return ctx.constrain(out, *_q_logical(mode))


def decode_attention(q, k_cache, v_cache, pos, ctx, mode: str,
                     bf16_compute: bool = False) -> jax.Array:
    """One-token attention against a (possibly sequence-sharded) cache.

    q: (B, 1, G, R, Dh); caches are HEADS-MAJOR (B, G, S, Dh) so the score
    and attend einsums have (b, g) as leading batch dims and contract on
    the minor axis — no full-cache transpose per layer per step (that
    layout churn cost ~2 TB/step at llama3-405b decode_32k; §Perf iter C).
    Plain softmax over S — GSPMD partitions the reductions over the
    seq-sharded cache into the flash-decoding combine.

    ``pos`` is the valid-prefix length: a scalar (uniform batch, the
    one-shot serve path) or a (B,) array of per-slot lengths (the
    continuous-batching engine, where slots hold requests of different
    ages). Entries at or beyond a slot's pos are masked, so KV written by
    a previous occupant of the slot — or by a right-padded bucketed
    prefill — is never read.
    """
    S = k_cache.shape[2]
    scale = q.shape[-1] ** -0.5
    # q/p ride in f32 (tiny); the cache side stays in its storage dtype —
    # on TPU this is the native mixed-precision MXU path. (The CPU backend
    # cannot execute a bf16xbf16->f32 dot thunk, which smoke tests would
    # hit if both operands were cast down.)
    qdt = k_cache.dtype if bf16_compute else jnp.float32
    s = jnp.einsum("bqgrd,bgsd->bgrqs", q.astype(qdt), k_cache,
                   preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(S)[None, :] < jnp.reshape(pos, (-1, 1))  # (B or 1, S)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrqs,bgsd->bqgrd", p.astype(qdt), v_cache,
                   preferred_element_type=jnp.float32)
    return ctx.constrain(o.astype(q.dtype), *_q_logical(mode))


def _pool_scale_grouped(scale: jax.Array) -> jax.Array:
    """Paged pool scale (G, 1, Dh) -> (1, 1, G, Dh) for grouped K/V."""
    return jnp.swapaxes(scale, 0, 1)[None]


def _paged_write(pool: dict, tables: jax.Array, positions: jax.Array,
                 k_vals: jax.Array, v_vals: jax.Array, page_size: int,
                 kv_spec) -> dict:
    """Scatter per-token K/V writes through a block table.

    pool: {"k"/"v": (n_pages, G, ps, Dh)[, "k_scale"/"v_scale": (G,1,Dh)]};
    tables: (max_pages,) one row or (B, max_pages); positions: (N,) token
    indices aligned with k_vals/v_vals (N, G, Dh). Positions past the
    table's coverage redirect to the garbage page (page 0): a retired
    slot's zombie writes must not clobber a live page (the dense cache got
    this isolation for free from per-slot rows).
    """
    ps = page_size
    page_idx = positions // ps
    off = positions % ps
    row = tables if tables.ndim == 2 else jnp.broadcast_to(
        tables[None], (positions.shape[0], tables.shape[0]))
    max_pages = row.shape[1]
    safe = jnp.minimum(page_idx, max_pages - 1)
    pt = jnp.take_along_axis(row, safe[:, None], axis=1)[:, 0]
    pt = jnp.where(page_idx < max_pages, pt, 0)
    out = dict(pool)
    for name, vals in (("k", k_vals), ("v", v_vals)):
        dst = pool[name]
        if kv_spec is not None and "k_scale" in pool:
            vals = kv_quantize(jnp.swapaxes(vals[:, None], 1, 2),
                               kv_spec, pool[f"{name}_scale"])[:, :, 0]
        out[name] = dst.at[pt, :, off, :].set(vals.astype(dst.dtype))
    return out


def _paged_gather(pool: dict, tables: jax.Array, kv_spec):
    """Pages -> contiguous heads-major K/V (the XLA fallback read path).

    Returns (k, v) shaped (B, G, max_pages * ps, Dh), dequantized when the
    pool holds codes. Same math as ``kernels.ref.gather_pages`` + dequant —
    the oracle the paged kernel is tested against.
    """
    def one(name):
        gathered = pool[name][tables]          # (B, max_pages, G, ps, Dh)
        B, n, G, ps, Dh = gathered.shape
        flat = jnp.transpose(gathered, (0, 2, 1, 3, 4)).reshape(
            B, G, n * ps, Dh)
        if kv_spec is not None and "k_scale" in pool:
            return kv_dequantize(flat, kv_spec, pool[f"{name}_scale"][None])
        return flat
    return one("k"), one("v")


def attn_forward(p: dict, x: jax.Array, cfg, ctx, rcfg, *,
                 positions: jax.Array, causal: bool = True,
                 cache: Optional[dict] = None, cache_pos=None,
                 xa: Optional[jax.Array] = None,
                 use_kernel: bool = False,
                 kv_spec=None, kv_kernel: bool = False,
                 kv_scales: Optional[dict] = None,
                 pages: Optional[jax.Array] = None,
                 page_size: Optional[int] = None,
                 paged_prefill: Optional[dict] = None):
    """Full attention layer. Returns (y, new_cache_kv or None).

    cache: {"k": (B,G,S,Dh), "v": ...} for decode (self) or precomputed
    cross k/v (xa is ignored then). xa: encoder states for cross-attention.

    Quantized KV cache (DESIGN.md §8): when ``kv_spec`` is a byte-wide
    fxp/pofx QuantSpec, cache "k"/"v" leaves hold quantization *codes* and
    ride next to static per-head-dim-channel "k_scale"/"v_scale" leaves.
    Decode quantizes the new token's K/V on write and attends through
    ``kernels.kv_flash_decode`` (``kv_kernel=True``: codes stream from HBM
    and dequantize in VMEM) or the XLA fallback (dequantize-on-read +
    ``decode_attention``). Prefill passes ``kv_scales`` instead of a cache:
    K/V are fake-quantized through the cache grid *before* flash attention
    so prefill sees exactly the values decode will read back — that
    equivalence is what makes the engine's evict -> re-prefill resume
    bit-identical under a lossy cache.

    Paged cache (DESIGN.md §10): decode passes the *pool* layer as
    ``cache`` ({"k"/"v": (n_pages, G, ps, Dh) pages, scales global
    (G, 1, Dh)}) plus ``pages`` (the (B, max_pages) block tables) and the
    static ``page_size`` — reads/writes indirect through the tables
    (``kv_flash_paged_decode`` or the gather fallback). Prefill passes
    ``paged_prefill`` = {pool, row, prefix_len, page_size}: the suffix
    attends to the ``prefix_len`` tokens already resident in shared pages
    (gathered + dequantized, ``bias_offset=prefix_len``) and its own K/V
    writes land in the pool through the row — the prefix-sharing admission
    path, batch-1 only.
    """
    B, Sq, _ = x.shape
    Dh = cfg.d_head
    # Head counts come from the weight leaves, not the config: inside a
    # manual-TP shard_map (DESIGN.md §9) each device holds H/tp q heads and
    # G/tp kv-head groups, and every reshape below must follow the local
    # shard. Outside TP the shapes equal the config's.
    H = p["wq"].shape[-2]
    G = p["wk"].shape[-2]
    R = H // G
    tp = ctx.axis_size("model")
    mode = attn_tp_mode(cfg.n_heads, cfg.n_kv_heads, tp)

    q = matmul_param(x, p["wq"], use_kernel=use_kernel).reshape(B, Sq, G, R, Dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)

    if xa is not None:
        # cross-attention: build k/v from encoder states (non-causal, no rope)
        k = matmul_param(xa, p["wk"], use_kernel=use_kernel).reshape(B, -1, G, Dh)
        v = matmul_param(xa, p["wv"], use_kernel=use_kernel).reshape(B, -1, G, Dh)
        if cfg.qk_norm:
            k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
        new_kv = {"k": k, "v": v}
        q, k, v = _maybe_expand(q, k, v, mode, H, R)
        k = ctx.constrain(k, *_kv_logical(mode))
        q = ctx.constrain(q, *_q_logical(mode))
        y = flash_attention(q, k, v, causal=False, q_chunk=rcfg.attn_q_chunk,
                            kv_chunk=rcfg.attn_kv_chunk, ctx=ctx, mode=mode)
    elif cache is not None and Sq == 1:
        if "k_static" in cache:  # precomputed cross-attention cache (no rope)
            q = ctx.constrain(q, *_q_logical(mode))
            y = decode_attention(q, cache["k_static"], cache["v_static"],
                                 cache["len"], ctx, mode,
                                 bf16_compute=rcfg.serve_bf16_compute)
            new_kv = None
        else:
            # decode: rope at current position, update cache, attend
            cos, sin = rotary_cos_sin(positions, Dh, cfg.rope_theta)
            q = apply_rotary(q.reshape(B, Sq, H, Dh), cos, sin).reshape(B, Sq, G, R, Dh)
            q = ctx.constrain(q, *_q_logical(mode))
            k = matmul_param(x, p["wk"], use_kernel=use_kernel).reshape(B, Sq, G, Dh)
            v = matmul_param(x, p["wv"], use_kernel=use_kernel).reshape(B, Sq, G, Dh)
            if cfg.qk_norm:
                k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
            k = apply_rotary(k, cos, sin)
            if pages is not None:
                # paged decode (DESIGN.md §10): cache is the page POOL; the
                # slot's tokens live wherever its block table points. Write
                # the new token's codes through the table, then attend via
                # the paged kernel (codes dequantize in VMEM per page) or
                # the gather fallback (materialize + dequantize, the
                # oracle's math).
                pos_b = jnp.broadcast_to(jnp.reshape(cache_pos, (-1,)), (B,))
                new_kv = _paged_write(cache, pages, pos_b, k[:, 0], v[:, 0],
                                      page_size, kv_spec)
                quant = kv_spec is not None and "k_scale" in cache
                if quant and kv_kernel:
                    from repro.kernels import kv_flash_paged_decode
                    o = kv_flash_paged_decode(
                        q[:, 0], new_kv["k"], cache["k_scale"], new_kv["v"],
                        cache["v_scale"], pages, pos_b + 1, kv_spec)
                    y = ctx.constrain(o[:, None].astype(q.dtype),
                                      *_q_logical(mode))
                else:
                    kf, vf = _paged_gather(new_kv, pages, kv_spec)
                    y = decode_attention(
                        q, kf, vf, pos_b + 1, ctx, mode,
                        bf16_compute=(not quant
                                      and rcfg.serve_bf16_compute))
                y = y.reshape(B, Sq, H * Dh).astype(x.dtype)
                out = ctx.psum(matmul_param(y, p["wo"],
                                            use_kernel=use_kernel))
                return out, new_kv
            # heads-major cache (B, G, S, Dh): in-place update of one column.
            # cache_pos is a scalar (uniform batch) or a (B,) array of
            # per-slot write positions (continuous batching) — the array
            # case vmaps the update so each slot writes at its own length.
            # Quantized caches write CODES: the new token's K/V quantizes
            # against the static channel scale, so full-precision K/V never
            # reaches HBM.
            quant = kv_spec is not None and "k_scale" in cache
            k_upd = jnp.swapaxes(k, 1, 2)
            v_upd = jnp.swapaxes(v, 1, 2)
            if quant:
                k_upd = kv_quantize(k_upd, kv_spec, cache["k_scale"])
                v_upd = kv_quantize(v_upd, kv_spec, cache["v_scale"])
            else:
                kdt = cache["k"].dtype
                k_upd = k_upd.astype(kdt)
                v_upd = v_upd.astype(kdt)
            zero = jnp.zeros((), jnp.int32)
            if getattr(cache_pos, "ndim", 0):
                def put(c, u, p):
                    return jax.lax.dynamic_update_slice(c, u, (zero, p, zero))
                k_cache = jax.vmap(put)(cache["k"], k_upd, cache_pos)
                v_cache = jax.vmap(put)(cache["v"], v_upd, cache_pos)
            else:
                k_cache = jax.lax.dynamic_update_slice(
                    cache["k"], k_upd, (zero, zero, cache_pos, zero))
                v_cache = jax.lax.dynamic_update_slice(
                    cache["v"], v_upd, (zero, zero, cache_pos, zero))
            k_cache = ctx.constrain(k_cache, "batch", None, "kv_seq", "head_dim")
            v_cache = ctx.constrain(v_cache, "batch", None, "kv_seq", "head_dim")
            if quant:
                new_kv = {"k": k_cache, "k_scale": cache["k_scale"],
                          "v": v_cache, "v_scale": cache["v_scale"]}
                if kv_kernel:
                    from repro.kernels import kv_flash_decode
                    o = kv_flash_decode(q[:, 0], k_cache, cache["k_scale"],
                                        v_cache, cache["v_scale"],
                                        cache_pos + 1, kv_spec)
                    y = ctx.constrain(o[:, None].astype(q.dtype),
                                      *_q_logical(mode))
                else:
                    # XLA fallback: dequantize-on-read + plain decode
                    # attention (CPU smoke / dry-run lowering path).
                    kf = kv_dequantize(k_cache, kv_spec, cache["k_scale"])
                    vf = kv_dequantize(v_cache, kv_spec, cache["v_scale"])
                    y = decode_attention(q, kf, vf, cache_pos + 1, ctx, mode)
            else:
                y = decode_attention(q, k_cache, v_cache, cache_pos + 1, ctx,
                                     mode,
                                     bf16_compute=rcfg.serve_bf16_compute)
                new_kv = {"k": k_cache, "v": v_cache}
    else:
        # train / prefill
        cos, sin = rotary_cos_sin(positions, Dh, cfg.rope_theta)
        q = apply_rotary(q.reshape(B, Sq, H, Dh), cos, sin).reshape(B, Sq, G, R, Dh)
        k = matmul_param(x, p["wk"], use_kernel=use_kernel).reshape(B, Sq, G, Dh)
        v = matmul_param(x, p["wv"], use_kernel=use_kernel).reshape(B, Sq, G, Dh)
        if cfg.qk_norm:
            k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
        k = apply_rotary(k, cos, sin)
        if paged_prefill is not None:
            # Paged admission prefill (DESIGN.md §10, batch-1): the first
            # ``prefix_len`` tokens of the context are already resident in
            # shared pages — gather + dequantize them, attend the suffix
            # against [prefix ; suffix] with ``bias_offset=prefix_len``
            # (the same kv-chunk boundaries a full dense prefill of the
            # whole context would use, so the suffix rows and the sampled
            # logits match the dense engine's), and write only the
            # suffix's codes through the block-table row.
            if B != 1:
                raise ValueError(
                    f"paged prefill is batch-1 (admission), got B={B}")
            pool = paged_prefill["pool"]
            row = paged_prefill["row"]
            prefix_len = int(paged_prefill["prefix_len"])
            ps = int(paged_prefill["page_size"])
            quant = kv_spec is not None and "k_scale" in pool
            if quant:
                ks = _pool_scale_grouped(pool["k_scale"])
                vs = _pool_scale_grouped(pool["v_scale"])
                k = kv_dequantize(kv_quantize(k, kv_spec, ks), kv_spec, ks,
                                  k.dtype)
                v = kv_dequantize(kv_quantize(v, kv_spec, vs), kv_spec, vs,
                                  v.dtype)
            new_kv = _paged_write(pool, row, prefix_len + jnp.arange(Sq),
                                  k[0], v[0], ps, kv_spec)
            if prefix_len > 0:
                npp = -(-prefix_len // ps)
                ids = jax.lax.slice_in_dim(row, 0, npp)

                def grouped_prefix(name):
                    t = pool[name][ids]            # (npp, G, ps, Dh)
                    if quant:
                        t = kv_dequantize(t, kv_spec,
                                          pool[f"{name}_scale"], k.dtype)
                    t = jnp.transpose(t, (0, 2, 1, 3)).reshape(
                        npp * ps, G, Dh)
                    return t[None, :prefix_len].astype(k.dtype)

                k = jnp.concatenate([grouped_prefix("k"), k], axis=1)
                v = jnp.concatenate([grouped_prefix("v"), v], axis=1)
        elif kv_spec is not None and kv_scales is not None:
            # Quantized-cache prefill: round K/V through the cache grid
            # BEFORE attending, and hand the codes back for the cache
            # write. Prefill thereby attends to exactly what decode will
            # dequantize later — the invariant behind bit-identical
            # evict -> re-prefill resume (scales are static, so the same
            # floats always re-quantize to the same codes).
            ks = jnp.swapaxes(kv_scales["k_scale"], 1, 2)  # (B,1,G,Dh)
            vs = jnp.swapaxes(kv_scales["v_scale"], 1, 2)
            kc = kv_quantize(k, kv_spec, ks)
            vc = kv_quantize(v, kv_spec, vs)
            k = kv_dequantize(kc, kv_spec, ks, k.dtype)
            v = kv_dequantize(vc, kv_spec, vs, v.dtype)
            new_kv = {"k": kc, "v": vc}     # codes, grouped heads
        else:
            new_kv = {"k": k, "v": v}       # cache keeps the grouped heads
        q, k, v = _maybe_expand(q, k, v, mode, H, R)
        q = ctx.constrain(q, *_q_logical(mode))
        k = ctx.constrain(k, *_kv_logical(mode))
        v = ctx.constrain(v, *_kv_logical(mode))
        y = flash_attention(q, k, v, causal=causal, q_chunk=rcfg.attn_q_chunk,
                            kv_chunk=rcfg.attn_kv_chunk, ctx=ctx, mode=mode,
                            bias_offset=(int(paged_prefill["prefix_len"])
                                         if paged_prefill is not None else 0))
    y = y.reshape(B, Sq, H * Dh).astype(x.dtype)
    # wo is row-sharded under manual TP (its contraction dim is the local
    # H*Dh shard): this psum is the block's one attention collective.
    out = ctx.psum(matmul_param(y, p["wo"], use_kernel=use_kernel))
    return out, new_kv

from .checkpoint import CheckpointManager
from .compression import posit_compressed_mean, compressed_grad_transform
from .straggler import StepTimeMonitor

__all__ = ["CheckpointManager", "posit_compressed_mean",
           "compressed_grad_transform", "StepTimeMonitor"]

"""Quantizer registry — ExPAN(N)D storage/compute formats as a pytree type.

A ``QuantSpec`` names one point of the paper's design space:

  kind = "fp32" | "bf16"        passthrough baselines
       | "fxp"                  FxP(M, F) linear quantization (paper baseline)
       | "posit"                Posit(N, ES) storage, full-precision compute
                                (the Posit-only comparator of Table 5)
       | "pofx"                 **the paper's format**: normalized Posit(N-1,
                                ES) storage, FxP(M, F=M-1) compute after PoFx

  path (pofx only) = "direct"   FP32 -> Posit   -> FxP   (Table 5 "Posit_FxP")
                   | "via_fxp"  FP32 -> FxP -> Posit -> FxP ("FxP_Posit_FxP")

  scale_mode: normalizer bringing weights into [-1, 1] (see core.fxp);
  "none" reproduces the paper's already-normalized assumption.

``QuantizedTensor`` is a registered pytree (codes + scale are leaves, spec is
static) so quantized params flow through jit/pjit/scan and checkpointing.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import fxp as _fxp
from . import normalized_posit as _np_
from . import posit as _posit
from .pofx import pofx_norm_lut

__all__ = ["QuantSpec", "QuantizedTensor", "quantize", "dequantize",
           "storage_bits", "validate_kv_spec", "kv_code_dtype", "kv_quantize",
           "kv_dequantize"]

_KINDS = ("fp32", "bf16", "fxp", "posit", "pofx")


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    kind: str = "bf16"
    N: int = 8            # posit total bit length (stored bits = N-1 for pofx)
    ES: int = 2
    M: int = 8            # FxP total bits
    F: int = 7            # FxP fraction bits (pofx forces F = M-1)
    path: str = "via_fxp"  # pofx quantization path
    scale_mode: str = "channel_pow2"
    rounding: str = "trunc"

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown quant kind {self.kind!r}")

    @property
    def stored_bits(self) -> int:
        """Bits per stored weight (the paper's storage accounting)."""
        if self.kind == "fp32":
            return 32
        if self.kind == "bf16":
            return 16
        if self.kind == "fxp":
            return self.M
        if self.kind == "posit":
            return self.N
        return self.N - 1  # pofx: normalized posit stores N-1 bits

    def code_dtype(self):
        b = self.stored_bits
        if b <= 8:
            return jnp.uint8
        if b <= 15:
            return jnp.int16
        return jnp.int32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    codes: jax.Array          # packed per-weight codes (or raw floats)
    scale: jax.Array          # normalizer, broadcastable against codes
    spec: QuantSpec

    @property
    def shape(self):
        return self.codes.shape

    @property
    def ndim(self):
        return self.codes.ndim

    def tree_flatten(self):
        return (self.codes, self.scale), self.spec

    @classmethod
    def tree_unflatten(cls, spec, children):
        return cls(children[0], children[1], spec)

    def dequantize(self, dtype=jnp.bfloat16):
        return dequantize(self, dtype)


def _as_f32(x):
    return jnp.asarray(x, dtype=jnp.float32)


def quantize(w, spec: QuantSpec, axis: Optional[int] = None) -> QuantizedTensor:
    """Quantize a float array into the storage format named by ``spec``."""
    w = _as_f32(w)
    if spec.kind in ("fp32", "bf16"):
        dt = jnp.float32 if spec.kind == "fp32" else jnp.bfloat16
        one = jnp.ones((1,) * max(w.ndim, 1), jnp.float32)
        return QuantizedTensor(w.astype(dt), one, spec)
    if axis is None and spec.scale_mode.startswith("channel"):
        axis = -1  # convention: last axis is the output-channel axis
    scale = _fxp.compute_scale(w, spec.scale_mode, axis)
    wn = w / scale
    if spec.kind == "fxp":
        codes = _fxp.fxp_quantize(wn, spec.M, spec.F)
        dt = jnp.int8 if spec.M <= 8 else jnp.int32
        return QuantizedTensor(codes.astype(dt), scale, spec)
    if spec.kind == "posit":
        codes = _posit.posit_encode(wn, spec.N, spec.ES)
        return QuantizedTensor(codes.astype(spec.code_dtype()), scale, spec)
    # pofx: optionally pre-round through the FxP grid (Table 5's good path),
    # then encode onto the normalized posit lattice.
    if spec.path == "via_fxp":
        wn = _fxp.fxp_dequantize(_fxp.fxp_quantize(wn, spec.M, spec.M - 1), spec.M - 1)
    codes = _np_.norm_encode(wn, spec.N, spec.ES)
    return QuantizedTensor(codes.astype(spec.code_dtype()), scale, spec)


def _codes_to_values(codes, spec: QuantSpec) -> jax.Array:
    """Integer codes -> unscaled float values through the FxP datapath.

    The ONE copy of the hardware decode both the weight path (dequantize)
    and the KV-cache path (kv_dequantize, and tile-wise the flash-decode
    kernel) must agree on bit-for-bit: fxp is a two's-complement shift;
    pofx goes stored posit -> bit-level LUT -> FxP(M, M-1) -> value.
    """
    if spec.kind == "fxp":
        return _fxp.fxp_dequantize(codes, spec.F)
    if spec.kind == "pofx":
        lut = jnp.asarray(pofx_norm_lut(spec.N, spec.ES, spec.M, spec.rounding))
        fxp_codes = jnp.take(lut, codes.astype(jnp.int32), axis=0)
        return _fxp.fxp_dequantize(fxp_codes, spec.M - 1)
    raise ValueError(f"no FxP decode path for kind {spec.kind!r}")


def dequantize(qt: QuantizedTensor, dtype=jnp.bfloat16) -> jax.Array:
    """Recover float values as the *hardware* would see them.

    pofx goes through the bit-level PoFx table: stored posit -> FxP(M, M-1)
    two's-complement -> value * scale.  This is the datapath of Fig. 7.
    """
    spec = qt.spec
    if spec.kind in ("fp32", "bf16"):
        return qt.codes.astype(dtype)
    if spec.kind == "posit":
        v = _posit.posit_decode(qt.codes, spec.N, spec.ES)
    else:  # fxp / pofx
        v = _codes_to_values(qt.codes, spec)
    return (v * qt.scale).astype(dtype)


def fxp_view(qt: QuantizedTensor):
    """(int8 codes, float rescale) pair for the int8 MXU MAC path."""
    spec = qt.spec
    if spec.kind == "fxp":
        return qt.codes.astype(jnp.int8), qt.scale * (1.0 / (1 << spec.F))
    if spec.kind == "pofx":
        lut = jnp.asarray(pofx_norm_lut(spec.N, spec.ES, spec.M, spec.rounding), jnp.int32)
        codes = jnp.take(lut, qt.codes.astype(jnp.int32), axis=0).astype(jnp.int8)
        return codes, qt.scale * (1.0 / (1 << (spec.M - 1)))
    raise ValueError(f"no FxP view for kind {spec.kind!r}")


# ---------------------------------------------------------------------------
# Quantized KV cache — elementwise code path for 4D (B, G, S, Dh) tensors
# ---------------------------------------------------------------------------
#
# The decode KV cache stores quantization *codes* (one byte-wide lane per
# element, streamed from HBM by kernels.kv_flash_decode) next to a STATIC
# per-head-dim-channel normalizer scale leaf. The scale must not depend on
# the data written so far: quantize-on-write is lossy, and the engine's
# evict -> re-prefill resume is bit-identical only because re-quantizing the
# same float always yields the same code — a running (data-dependent) scale
# would re-scale history and corrupt resumed streams (DESIGN.md §8).
# Unlike the weight path there is no QuantizedTensor wrapper here: cache
# leaves must flatten 1:1 against ``LM.cache_logical`` for the engine's slot
# scatter, so codes and scale travel as sibling dict leaves.


def validate_kv_spec(spec: Optional[QuantSpec]) -> Optional[QuantSpec]:
    """Check a spec is usable as a KV-cache format; returns it (or None).

    bf16/fp32 mean "unquantized cache" and normalize to None. Quantized
    caches require byte-wide codes (stored_bits <= 8) of a kind with an FxP
    decode path the kernel implements: fxp or pofx.
    """
    if spec is None or spec.kind in ("bf16", "fp32"):
        return None
    if spec.kind not in ("fxp", "pofx"):
        raise ValueError(
            f"kv cache format must be fxp or pofx (got {spec.kind!r}): the "
            "flash-decode kernel dequantizes through the FxP datapath")
    if spec.stored_bits > 8:
        raise ValueError(
            f"kv cache codes must be byte-wide (stored_bits <= 8, got "
            f"{spec.stored_bits}): the cache streams uint8/int8 code tiles")
    if spec.kind == "pofx" and spec.rounding != "trunc":
        raise ValueError(
            f"kv cache pofx specs must use trunc rounding (got "
            f"{spec.rounding!r}): the flash-decode kernel's bit-level VPU "
            "decode truncates, and the XLA fallback must match it "
            "code-for-code")
    return spec


def kv_code_dtype(spec: QuantSpec):
    """Cache code dtype: int8 two's-complement for fxp, uint8 posit codes."""
    return jnp.int8 if spec.kind == "fxp" else jnp.uint8


def kv_quantize(x, spec: QuantSpec, scale) -> jax.Array:
    """Quantize K/V values into cache codes. Elementwise over any shape.

    ``scale`` is the static per-head-dim-channel normalizer leaf (typically
    (B, G, 1, Dh), broadcastable against ``x``); values outside the format's
    range after normalization saturate, exactly as the weight path does.
    """
    wn = _as_f32(x) / scale
    if spec.kind == "fxp":
        return _fxp.fxp_quantize(wn, spec.M, spec.F).astype(jnp.int8)
    if spec.kind != "pofx":
        raise ValueError(f"no kv code path for kind {spec.kind!r}")
    if spec.path == "via_fxp":
        wn = _fxp.fxp_dequantize(_fxp.fxp_quantize(wn, spec.M, spec.M - 1),
                                 spec.M - 1)
    return _np_.norm_encode(wn, spec.N, spec.ES).astype(jnp.uint8)


def kv_dequantize(codes, spec: QuantSpec, scale, dtype=jnp.float32) -> jax.Array:
    """Recover K/V values from cache codes (the XLA fallback / oracle path).

    This is the same math ``kernels.kv_flash_decode`` runs tile-wise in
    VMEM: codes -> FxP two's complement -> value * scale. It shares
    ``_codes_to_values`` with the weight path so the decode the
    kernel-vs-fallback and evict-resume contracts depend on has one copy.
    """
    return (_codes_to_values(codes, spec) * scale).astype(dtype)


def storage_bits(qt: QuantizedTensor) -> int:
    """Total stored parameter bits (codes bit-packed + fp32 scales)."""
    n = int(np.prod(qt.codes.shape)) if qt.codes.ndim else 1
    scale_n = int(np.prod(qt.scale.shape)) if qt.scale.ndim else 1
    if qt.spec.kind in ("fp32", "bf16"):
        return n * qt.spec.stored_bits
    return n * qt.spec.stored_bits + scale_n * 32

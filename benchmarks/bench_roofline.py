"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and emits
the per-(arch x shape x mesh) table: three terms in seconds, the dominant
bound, MFU upper bound, and MODEL_FLOPS/HLO_FLOPS.
"""
from __future__ import annotations

import glob
import json
import os

from .common import write_csv

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_cells(mesh=None):
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        rec = json.load(open(path))
        if mesh and rec.get("mesh") != mesh:
            continue
        cells.append(rec)
    return cells


def run():
    rows = []
    ok = skipped = failed = 0
    for rec in load_cells():
        if rec.get("skipped"):
            skipped += 1
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "quant": rec.get("quant"),
                         "status": "skip:" + rec.get("reason", "")[:40]})
            continue
        if not rec.get("ok"):
            failed += 1
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": rec["mesh"], "quant": rec.get("quant"),
                         "status": "FAIL"})
            continue
        ok += 1
        r = rec["roofline"]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "quant": rec.get("quant"), "status": "ok",
            "compute_ms": round(r["compute_s"] * 1e3, 2),
            "memory_ms": round(r["memory_s"] * 1e3, 2),
            "collective_ms": round(r["collective_s"] * 1e3, 2),
            "bound": r["bound"],
            "mfu_bound": round(r["mfu_bound"], 4),
            "useful_flops_ratio": round(r["useful_flops_ratio"], 3),
            "live_GiB_per_dev": round(
                rec.get("memory", {}).get("live_bytes_per_device", 0) / 2**30,
                2),
            "compile_s": rec.get("compile_s"),
        })
    write_csv("roofline", rows)
    return rows, {"cells_ok": ok, "cells_skipped": skipped,
                  "cells_failed": failed}

"""Paged KV cache subsystem tests (DESIGN.md §10).

Four layers of coverage:

* block-manager invariants (``launch.paging``) via the tests/proptest.py
  harness: alloc/free/refcount consistency (no double free, refcounts
  recomputable from reachability), copy-on-write on mid-page prefix
  boundaries, and the radix index never returning a page whose token
  prefix or kv_spec mismatches the query;
* the paged flash-decode kernel against its gather oracle
  (``kernels.ref.kv_flash_paged_decode_ref``) over ragged block tables;
* model level: ``prefill_paged`` / paged ``decode_step`` agree with the
  dense cache path, including shared-prefix suffix prefill;
* engine level — the acceptance contract: the paged engine's token
  streams are IDENTICAL to the dense engine's (greedy AND sampled,
  kernels on/off, tp in {1, 2}, evict -> resume under kv=fxp8), via the
  shared tests/differential.py harness, plus prefix-cache hit-rate
  accounting and pool-pressure reclaim.

Sharing differentials pin f32 activations like the TP suite (DESIGN.md
§9): token identity across reordered float accumulations is the contract
at the precision where it is hardware-independent.
"""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from differential import (assert_token_identical, differential_engines,
                          make_engine, make_request as _req)
from proptest import Choice, Ints, given
from repro.core.quantizers import QuantSpec, kv_quantize
from repro.launch.engine import ServeEngine
from repro.launch.paging import (GARBAGE_PAGE, PageAllocator, PagedKVManager,
                                 RadixPrefixIndex)

FXP8 = QuantSpec(kind="fxp", M=8, F=7)
POFX8 = QuantSpec(kind="pofx", N=8, ES=2)


def _f32_rcfg():
    from repro.configs import RunConfig
    return RunConfig(remat="none", activation_dtype="f32")


# ---------------------------------------------------------------------------
# PageAllocator / PagedKVManager invariants (proptest harness)
# ---------------------------------------------------------------------------


@given(seed=11, examples=30, ops=Ints(0, 2, shape=(40,)),
       n_pages=Choice([4, 7, 16]))
def test_allocator_no_double_free_and_partition(ops, n_pages):
    """Random alloc/incref/decref traffic: refcounts never drift, freed
    pages never stay referenced, double frees raise."""
    alloc = PageAllocator(n_pages)
    live = []
    rng = np.random.default_rng(int(np.sum(ops)) + n_pages)
    for op in np.asarray(ops).reshape(-1):
        if op == 0 or not live:
            pid = alloc.alloc()
            if pid is None:
                assert alloc.n_free == 0
                continue
            assert pid != GARBAGE_PAGE
            live.append(pid)
        elif op == 1:
            pid = live[int(rng.integers(len(live)))]
            alloc.incref(pid)
            live.append(pid)
        else:
            pid = live.pop(int(rng.integers(len(live))))
            alloc.decref(pid)
        held = {p: live.count(p) for p in set(live)}
        for p in range(1, n_pages):
            assert alloc.refcount(p) == held.get(p, 0)
        assert alloc.n_resident == len(set(live))
    while live:
        alloc.decref(live.pop())
    assert alloc.n_free == n_pages - 1
    with pytest.raises(ValueError, match="double free|unallocated"):
        alloc.decref(1)


@given(seed=12, examples=25, toks=Ints(0, 3, shape=(3, 24)),
       ps=Choice([2, 4, 5]), n_req=Choice([2, 3]))
def test_manager_lifecycle_refcounts_and_prefix_truth(toks, ps, n_req):
    """Random admit/ensure/suspend/release traffic over a tiny token
    alphabet (maximal prefix collisions): after every operation the
    recomputed refcounts match (``check()``), and every admission's
    matched prefix is literally a prefix of the submitted tokens."""
    toks = np.asarray(toks)
    max_pages = -(-toks.shape[1] // ps)
    mgr = PagedKVManager(64, ps, max_pages, spec_key="fxp8")
    rng = np.random.default_rng(int(toks.sum()))
    admitted = {}
    for rid in range(int(n_req)):
        seq = [int(t) for t in toks[rid % toks.shape[0]]]
        plan = mgr.admit(rid, seq, len(seq))
        mgr.check()
        assert 0 <= plan.prefix_len <= len(seq) - 1
        # CoW exactly when the prefix ends mid-page; the copied page is
        # fresh (refcount 1, owned by this sequence)
        assert bool(plan.copies) == bool(plan.prefix_len % ps)
        for src, dst in plan.copies:
            assert mgr.alloc.refcount(dst) == 1 and dst != src
        # matched pages must cover a literal prefix: pages registered for
        # these tokens earlier — verify against the index's own key walk
        re_pids, re_hit = mgr.index.match(seq, "fxp8")
        assert re_hit >= plan.prefix_len
        mgr.register(rid, seq, len(seq))
        mgr.check()
        admitted[rid] = seq
    for rid, seq in admitted.items():
        mgr.ensure(rid, min(len(seq) + int(rng.integers(0, 2 * ps)),
                            max_pages * ps))
        mgr.check()
    for rid, seq in admitted.items():
        if rng.random() < 0.5:
            mgr.suspend(rid, seq, len(seq))
        else:
            mgr.release(rid)
        mgr.check()
    # a foreign kv_spec never matches
    pids, hit = mgr.index.match(admitted[0], "pofx8es2")
    assert pids == [] and hit == 0


@given(seed=13, examples=30, toks=Ints(0, 2, shape=(4, 16)),
       ps=Choice([2, 4]))
def test_radix_index_never_returns_mismatched_prefix(toks, ps):
    """Adversarial insert/match traffic: whatever the tree state, a match
    must count only tokens that literally prefix the query, and every
    returned page must have been inserted for exactly that token run."""
    toks = np.asarray(toks)
    alloc = PageAllocator(128)
    idx = RadixPrefixIndex(alloc, ps, spec_key="s")
    truth = {}                       # pid -> token run it was inserted for
    for row in toks:
        seq = [int(t) for t in row]
        n_pages = -(-len(seq) // ps)
        pids = [alloc.alloc() for _ in range(n_pages)]
        idx.insert(seq, pids, len(seq))
        for i, pid in enumerate(pids):
            # the index adopts a pid only for NEW nodes (refcount 2 =
            # caller + index); non-adopted pids free below and may be
            # reallocated, so only adopted ones enter the shadow map
            if alloc.refcount(pid) == 2:
                truth[pid] = seq[i * ps:(i + 1) * ps]
        for pid in pids:             # caller's own refs returned
            alloc.decref(pid)
    for row in toks[::-1]:
        seq = [int(t) for t in row]
        pids, hit = idx.match(seq, "s")
        assert hit <= len(seq)
        covered = 0
        for i, pid in enumerate(pids):
            want = seq[covered:min(covered + ps, hit)]
            got = truth[pid][:len(want)]
            assert got == want, (pid, got, want)
            covered += len(want)
        assert covered == hit
        assert idx.match(seq, "OTHER") == ([], 0)


def test_manager_admit_page_align_bounds_prefix():
    """page_align=True rounds the hit down to a page boundary (no CoW, no
    mid-page suffix start) — the engine couples it to prompt bucketing so
    prefix_len, a static jit arg, has at most max_pages variants."""
    ps = 4
    mgr = PagedKVManager(32, ps, 8, spec_key="fxp8")
    seq = list(range(30, 44))            # 14 tokens
    mgr.admit(0, seq, 14)
    mgr.register(0, seq, 14)
    mgr.release(0)
    aligned = mgr.admit(1, seq, 14, page_align=True)
    assert aligned.prefix_len == 12 and not aligned.copies   # 13 -> 12
    mgr.release(1)
    exact = mgr.admit(2, seq, 14)        # capped at len - 1, mid-page
    assert exact.prefix_len == 13 and exact.copies
    mgr.release(2)
    mgr.check()


def test_paged_bucketed_prefill_identical(tiny):
    """prompt_bucket > 1 (bounded compile variants) with prefix sharing:
    page-aligned hits, streams still identical to the bucketed dense
    engine."""
    cfg, model, params = tiny("yi-9b", kv_spec=FXP8, rcfg=_f32_rcfg())
    prompt = np.random.RandomState(9).randint(0, cfg.vocab_size, 11)

    def reqs():
        from repro.launch.engine import Request, SamplingParams
        return [Request(rid=i, prompt=prompt, max_new=4,
                        sampling=SamplingParams(), arrival=float(3 * i))
                for i in range(2)]

    ref = {s.req.rid: s.out for s in make_engine(
        model, params, max_len=32, prompt_bucket=4).run(reqs())}
    eng = make_engine(model, params, max_len=32, prompt_bucket=4,
                      paged=True, page_size=4)
    got = {s.req.rid: s.out for s in eng.run(reqs())}
    assert_token_identical(got, ref, label="paged bucketed")
    st = eng.stats()
    assert st["prefix_hit_tokens"] == 8      # 10 usable -> aligned to 8
    assert st["cow_copies"] == 0             # aligned: no mid-page start
    eng._pager.check()


def test_manager_pool_exhaustion_raises_and_reclaims():
    ps, max_pages = 2, 4
    mgr = PagedKVManager(6, ps, max_pages, spec_key="fxp8")  # 5 usable
    a = list(range(10, 18))
    mgr.admit(0, a, 8)               # 4 pages
    mgr.register(0, a, 8)
    mgr.check()
    # pool nearly full: a second distinct admission must reclaim indexed
    # pages once rid 0 releases, and raise while rid 0 still holds them
    # (the failed admit rolls back cleanly — check() passes after it)
    with pytest.raises(RuntimeError, match="exhausted"):
        mgr.admit(1, list(range(20, 28)), 8)
    mgr.check()
    mgr.release(0)                   # index still holds rid 0's pages
    mgr.check()
    plan = mgr.admit(2, list(range(20, 28)), 8)   # reclaim makes room
    assert plan.prefix_len == 0
    mgr.check()


# ---------------------------------------------------------------------------
# Paged kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec", [FXP8, POFX8])
def test_paged_kernel_matches_oracle(spec):
    from repro.kernels import kv_flash_paged_decode
    from repro.kernels.ref import kv_flash_paged_decode_ref

    rng = np.random.default_rng(0)
    B, G, R, Dh, ps, n_pages, max_pages = 3, 2, 4, 16, 8, 10, 3
    ks = jnp.asarray(np.exp2(rng.integers(0, 2, (G, 1, Dh))), jnp.float32)
    vs = jnp.ones((G, 1, Dh), jnp.float32)
    kc = kv_quantize(jnp.asarray(
        rng.uniform(-0.9, 0.9, (n_pages, G, ps, Dh)), jnp.float32) * ks,
        spec, ks)
    vc = kv_quantize(jnp.asarray(
        rng.uniform(-0.9, 0.9, (n_pages, G, ps, Dh)), jnp.float32), spec, vs)
    q = jnp.asarray(rng.normal(size=(B, G, R, Dh)), jnp.float32)
    tables = jnp.asarray(rng.integers(0, n_pages, (B, max_pages)), jnp.int32)
    pos = jnp.asarray([5, 17, 24], jnp.int32)     # ragged, incl. full
    out = kv_flash_paged_decode(q, kc, ks, vc, vs, tables, pos, spec)
    ref = kv_flash_paged_decode_ref(q, kc, ks, vc, vs, tables, pos, spec)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_kernel_validates_layouts():
    from repro.kernels import kv_flash_paged_decode

    G, R, Dh, ps = 2, 2, 8, 4
    q = jnp.zeros((1, G, R, Dh))
    pool = jnp.zeros((4, G, ps, Dh), jnp.int8)
    good = jnp.ones((G, 1, Dh), jnp.float32)
    tables = jnp.zeros((1, 2), jnp.int32)
    pos = jnp.asarray([3])
    with pytest.raises(ValueError, match="global per-head-dim-channel"):
        kv_flash_paged_decode(q, pool, jnp.ones((1, G, 1, Dh)), pool, good,
                              tables, pos, FXP8)
    with pytest.raises(ValueError, match="pool shape mismatch"):
        kv_flash_paged_decode(q, pool, good, jnp.zeros((5, G, ps, Dh),
                                                       jnp.int8),
                              good, tables, pos, FXP8)
    with pytest.raises(ValueError, match="tables must be"):
        kv_flash_paged_decode(q, pool, good, pool, good,
                              jnp.zeros((3,), jnp.int32), pos, FXP8)


# ---------------------------------------------------------------------------
# Model level: paged prefill/decode vs the dense cache path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch,spec", [("yi-9b", None), ("yi-9b", FXP8),
                                       ("moonshot-v1-16b-a3b", FXP8)])
def test_prefill_paged_matches_dense_prefill(tiny, arch, spec):
    """With an identity-ish block table, paged prefill produces the same
    last-token logits as dense prefill (bit-exact: same flash chunking,
    same fake-quant grid) and paged decode follows the dense tokens."""
    cfg, model, params = tiny(arch, kv_spec=spec)
    P, ps, max_len = 7, 4, 24
    toks = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab_size, (1, P)), jnp.int32)
    dc = model.init_cache(1, max_len)
    dc, dlg = model.prefill(params, toks, cache=dc)
    pc = model.init_paged_cache(1, max_len, n_pages=16, page_size=ps)
    mp = pc["pages"].shape[1]
    pc["pages"] = pc["pages"].at[0].set(jnp.arange(1, mp + 1, dtype=jnp.int32))
    pc, plg = model.prefill_paged(params, toks, cache=pc,
                                  slot=jnp.asarray(0),
                                  length=jnp.asarray(P), prefix_len=0)
    np.testing.assert_array_equal(np.asarray(dlg), np.asarray(plg))
    dc["pos"] = jnp.broadcast_to(dc["pos"], (1,))
    tok = jnp.argmax(dlg, -1)[:, None]
    for i in range(3):
        dc, dlg = model.decode_step(params, dc, tok)
        pc, plg = model.decode_step(params, pc, tok)
        assert int(jnp.argmax(dlg)) == int(jnp.argmax(plg)), i
        tok = jnp.argmax(dlg, -1)[:, None]


def test_prefill_paged_shared_prefix_bit_identical(tiny):
    """A suffix prefill against resident prefix pages yields the same
    logits as prefilling the whole context — the prefix-sharing admission
    invariant (same Skv, same kv-chunk boundaries, same codes)."""
    cfg, model, params = tiny("yi-9b", kv_spec=FXP8)
    P, ps, max_len = 7, 4, 24
    toks = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab_size, (1, P)), jnp.int32)
    pc = model.init_paged_cache(2, max_len, n_pages=16, page_size=ps)
    mp = pc["pages"].shape[1]
    pc["pages"] = pc["pages"].at[0].set(jnp.arange(1, mp + 1, dtype=jnp.int32))
    pc, full = model.prefill_paged(params, toks, cache=pc,
                                   slot=jnp.asarray(0),
                                   length=jnp.asarray(P), prefix_len=0)
    row1 = np.zeros(mp, np.int32)
    row1[0] = 1                                  # share slot 0's page 0
    row1[1:] = np.arange(8, 8 + mp - 1)
    pc["pages"] = pc["pages"].at[1].set(jnp.asarray(row1))
    pc, shared = model.prefill_paged(params, toks[:, ps:], cache=pc,
                                     slot=jnp.asarray(1),
                                     length=jnp.asarray(P - ps),
                                     prefix_len=ps)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(shared))


def test_init_paged_cache_layout_and_rejections(tiny):
    cfg, model, params = tiny("yi-9b", kv_spec=FXP8)
    cache = model.init_paged_cache(2, 24, n_pages=9, page_size=4)
    assert cache["kv"]["k"].dtype == jnp.int8
    assert cache["kv"]["k"].shape[1:] == (9, cfg.n_kv_heads, 4, cfg.d_head)
    assert cache["kv"]["k_scale"].shape[1:] == (cfg.n_kv_heads, 1,
                                                cfg.d_head)
    assert cache["pages"].shape == (2, 6)
    n = len(jax.tree_util.tree_leaves(cache))
    log = jax.tree_util.tree_flatten(
        model.paged_cache_logical(),
        is_leaf=lambda x: isinstance(x, tuple))[0]
    assert n == len(log)
    for arch in ("falcon-mamba-7b", "zamba2-1.2b"):
        _, m2, _ = tiny(arch)
        with pytest.raises(ValueError, match="attention-only"):
            m2.init_paged_cache(1, 16, n_pages=4, page_size=4)
    _, m3, _ = tiny("yi-9b")
    with pytest.raises(ValueError, match="attention-only"):
        ServeEngine(*tiny("zamba2-1.2b")[1:3], paged=True)


# ---------------------------------------------------------------------------
# Engine: the dense-vs-paged differential contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("spec,use_kernel,temp", [
    (None, False, 0.0),
    (FXP8, False, 0.7),
    (FXP8, True, 0.0),
    (POFX8, True, 0.7),
])
def test_paged_engine_token_identical(tiny, spec, use_kernel, temp):
    """The acceptance contract: greedy and sampled streams identical to
    the dense engine, quantized KV kernels on and off."""
    quant = "pofx8" if use_kernel else None
    cfg, model, params = tiny("yi-9b", kv_spec=spec, use_kernel=use_kernel)
    if quant:
        from repro.nn.models import apply_policy
        params = apply_policy(params, quant)
    differential_engines(
        oracle=lambda: make_engine(model, params),
        variants={"paged": lambda: make_engine(model, params, paged=True,
                                               page_size=8)},
        requests=lambda: [_req(i, cfg.vocab_size, max_new=5, temp=temp,
                               top_k=8 if temp else 0, arrival=float(i))
                          for i in range(3)])


def test_paged_engine_moe_token_identical(tiny):
    cfg, model, params = tiny("moonshot-v1-16b-a3b", drop_free=True,
                              kv_spec=FXP8)
    differential_engines(
        oracle=lambda: make_engine(model, params, max_len=32),
        variants={"paged": lambda: make_engine(model, params, max_len=32,
                                               paged=True, page_size=8)},
        requests=lambda: [_req(i, cfg.vocab_size, max_new=4,
                               arrival=float(i)) for i in range(3)])


def test_paged_evict_resume_identical_and_reattaches(tiny):
    """Evict -> resume under kv=fxp8: the resumed stream matches the
    UNINTERRUPTED dense run, and resume re-attaches the evicted pages (a
    one-token prefill: prefix_hit_tokens grows by the context length - 1)."""
    cfg, model, params = tiny("yi-9b", kv_spec=FXP8)
    mk = lambda: [_req(i, cfg.vocab_size, max_new=7, temp=0.7, top_k=8)
                  for i in range(3)]
    ref = {s.req.rid: s.out for s in make_engine(model, params).run(mk())}

    eng = make_engine(model, params, paged=True, page_size=4)
    for r in mk():
        eng.submit(r)
    eng.admit_ready()
    eng.step()
    victim = eng.active_rids[0]
    before = eng.stats()["prefix_hit_tokens"]
    eng.evict(victim)
    while eng.pending_rids or eng.active_rids:
        eng.admit_ready()
        eng.step()
    got = {rid: st.out for rid, st in eng._states.items()}
    assert_token_identical(got, ref, label="paged evict+resume",
                           oracle_label="dense uninterrupted")
    assert eng._states[victim].n_evictions == 1
    # resume matched everything the evicted slot had written
    assert eng.stats()["prefix_hit_tokens"] > before
    eng._pager.check()


def test_paged_prefix_sharing_hits_and_identity(tiny):
    """K requests sharing one system prompt: every admission after the
    first hits the index, stats account the skipped prefill tokens
    (context - 1 per full-duplicate admission), and streams still match
    the dense engine. f32 activations pin the sharing differential the
    way DESIGN.md §9 pins TP."""
    cfg, model, params = tiny("yi-9b", kv_spec=FXP8, rcfg=_f32_rcfg())
    prompt = np.random.RandomState(7).randint(0, cfg.vocab_size, 12)

    def reqs():
        from repro.launch.engine import Request, SamplingParams
        return [Request(rid=i, prompt=prompt, max_new=4,
                        sampling=SamplingParams(), arrival=float(3 * i))
                for i in range(3)]

    ref = {s.req.rid: s.out
           for s in make_engine(model, params, max_len=32).run(reqs())}
    eng = make_engine(model, params, max_len=32, paged=True, page_size=4)
    got = {s.req.rid: s.out for s in eng.run(reqs())}
    assert_token_identical(got, ref, label="paged shared-prefix")
    st = eng.stats()
    assert st["prefix_hits"] == 2
    # identical context of 12 tokens -> each later admission skips 11
    # (one token must prefill to produce logits)
    assert st["prefix_hit_tokens"] == 2 * (len(prompt) - 1)
    assert st["prefix_hit_rate"] == pytest.approx(2 / 3)
    assert st["cow_copies"] >= 1          # 11 % 4 != 0: mid-page boundary
    eng._pager.check()


def test_paged_pool_pressure_reclaims_not_corrupts(tiny):
    """A pool with zero headroom beyond the running slots: index holdings
    must be reclaimed to admit new work, and the streams still match the
    dense engine (a reclaimed prefix just re-prefills)."""
    cfg, model, params = tiny("yi-9b", kv_spec=FXP8)
    mk = lambda: [_req(i, cfg.vocab_size, max_new=4, arrival=float(2 * i))
                  for i in range(4)]
    ref = {s.req.rid: s.out
           for s in make_engine(model, params, max_len=24).run(mk())}
    # requests top out at 12 context tokens = 3 pages; 2 slots x 3 pages
    # + garbage = the minimal pool, so any index retention from a finished
    # request must be reclaimed before the next admission fits
    eng = make_engine(model, params, max_len=24, paged=True, page_size=4,
                      n_pages=7)
    got = {s.req.rid: s.out for s in eng.run(mk())}
    assert_token_identical(got, ref, label="paged under pool pressure")
    assert eng._pager.pages_reclaimed > 0
    eng._pager.check()


def test_paged_stats_surface(tiny):
    cfg, model, params = tiny("yi-9b", kv_spec=FXP8)
    eng = make_engine(model, params, paged=True, page_size=8)
    eng.run([_req(i, cfg.vocab_size, max_new=3) for i in range(2)])
    st = eng.stats()
    for key in ("prefix_hit_rate", "prefix_hit_tokens", "resident_pages",
                "pages_freed", "cow_copies"):
        assert key in st, key
    dense = make_engine(model, params)
    assert "prefix_hit_rate" not in dense.stats()


# ---------------------------------------------------------------------------
# Tensor parallel: tp=2 paged == tp=1 dense (in-process on the CI
# multi-device job; subprocess smoke keeps tier-1 single-device coverage)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def jax4():
    if jax.device_count() < 4:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count"
                    "=4 (CI multi-device job; tier-1 coverage comes from "
                    "test_paged_tp_subprocess_smoke)")
    return jax


def test_paged_tp_token_identical(jax4, tiny):
    from repro.launch.mesh import make_tp_mesh

    cfg, model1, params = tiny("yi-9b", rcfg=_f32_rcfg(), kv_spec=FXP8)
    _, model2, _ = tiny("yi-9b", rcfg=_f32_rcfg(), kv_spec=FXP8,
                        mesh=make_tp_mesh(2))
    prompt = np.random.RandomState(7).randint(0, cfg.vocab_size, 12)

    def reqs():
        from repro.launch.engine import Request, SamplingParams
        out = [_req(i, cfg.vocab_size, max_new=5, temp=0.7, top_k=8,
                    arrival=float(i)) for i in range(2)]
        out += [Request(rid=2 + i, prompt=prompt, max_new=4,
                        sampling=SamplingParams(), arrival=float(2 + i))
                for i in range(2)]
        return out

    differential_engines(
        oracle=lambda: make_engine(model1, params, max_len=32),
        variants={"paged tp=2": lambda: make_engine(
            model2, params, max_len=32, paged=True, page_size=4)},
        requests=reqs)


def test_paged_tp_subprocess_smoke():
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys; sys.path.insert(0, "src")
import numpy as np, jax
from repro.configs import ARCHS, RunConfig, smoke
from repro.core.quantizers import QuantSpec
from repro.launch.engine import Request, SamplingParams, ServeEngine
from repro.launch.mesh import make_tp_mesh
from repro.nn.models import build_model

cfg = smoke(ARCHS["yi-9b"])
rcfg = RunConfig(remat="none", activation_dtype="f32")
spec = QuantSpec(kind="fxp", M=8, F=7)
params = build_model(cfg, rcfg).init(jax.random.PRNGKey(0))
prompt = np.random.RandomState(7).randint(0, cfg.vocab_size, 10)
def reqs():
    out = [Request(rid=i,
                   prompt=np.random.RandomState(i).randint(0, cfg.vocab_size, 8),
                   max_new=4, sampling=SamplingParams(), arrival=float(i))
           for i in range(2)]
    out.append(Request(rid=2, prompt=prompt, max_new=3,
                       sampling=SamplingParams(), arrival=2.0))
    out.append(Request(rid=3, prompt=prompt, max_new=3,
                       sampling=SamplingParams(), arrival=3.0))
    return out
dense = ServeEngine(build_model(cfg, rcfg, kv_spec=spec), params,
                    n_slots=2, max_len=24, chunk=3)
ref = {s.req.rid: s.out for s in dense.run(reqs())}
paged = ServeEngine(build_model(cfg, rcfg, mesh=make_tp_mesh(2),
                                kv_spec=spec), params,
                    n_slots=2, max_len=24, chunk=3, paged=True, page_size=4)
got = {s.req.rid: s.out for s in paged.run(reqs())}
assert got == ref, (got, ref)
assert paged.stats()["prefix_hit_tokens"] > 0
print("OK paged-tp-differential")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=1200)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK paged-tp-differential" in r.stdout

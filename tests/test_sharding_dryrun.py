"""Sharding + dry-run machinery on a small in-process device grid.

The full 512-device dry-run runs via launch/dryrun.py subprocesses (it must
own XLA_FLAGS); here we validate the same machinery — sharding rules,
state/cache sharding trees, lower+compile, HLO cost parser — on an 8-device
grid, plus the posit8 cross-pod gradient path and elastic restore.
"""
import subprocess
import sys
import os

import numpy as np
import pytest

_N_DEV = 8


@pytest.fixture(scope="module")
def jax8():
    os.environ.setdefault("XLA_FLAGS", "")
    import jax
    if jax.device_count() < _N_DEV:
        pytest.skip("needs xla_force_host_platform_device_count (see "
                    "test_dryrun_subprocess)")
    return jax


def test_sharding_rules_divisibility_fallback():
    import jax
    from repro.nn.sharding import make_ctx
    if jax.device_count() != 1:
        pytest.skip("single-device check")
    ctx = make_ctx(None)
    import jax.numpy as jnp
    x = jnp.zeros((4, 6))
    assert ctx.constrain(x, "batch", "mlp") is x  # no mesh: no-op


def test_dryrun_subprocess_small_mesh(tmp_path):
    """End-to-end: lower+compile a smoke arch on 8 fake devices, parse HLO,
    roofline terms present. Mirrors launch/dryrun.py in miniature."""
    import jax
    if not hasattr(jax, "shard_map"):
        # posit8 grad compression runs shard_map manual over "pod" with
        # data/model left auto; old-API jax (experimental.shard_map + this
        # container's XLA) CHECK-fails on that partial-manual partition
        # (hlo_sharding_util IsManualSubgroup). Needs jax>=0.6.
        pytest.skip("partial-manual shard_map unsupported by this jax/XLA")
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, dataclasses, json
from repro.configs import ARCHS, RunConfig, smoke
from repro.configs.base import ShapeConfig
from repro.nn.models import build_model, input_specs
from repro.launch.train import (make_train_step, abstract_train_state,
                                state_shardings, batch_shardings)
from repro.launch.hlo_parser import analyze_hlo
from repro.launch.hlo_analysis import roofline_terms

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
cfg = smoke(ARCHS["yi-9b"])
rcfg = RunConfig(remat="block", sequence_parallel=True, microbatch=2,
                 grad_compression="posit8")
model = build_model(cfg, rcfg, mesh=mesh)
state_abs = abstract_train_state(model)
ss = state_shardings(model, state_abs)
shape = ShapeConfig("t", 64, 8, "train")
batch_abs = input_specs(cfg, shape)
bs = batch_shardings(model, batch_abs)
step = make_train_step(model, mesh)
with mesh:
    compiled = jax.jit(step, in_shardings=(ss, bs), out_shardings=(ss, None),
                       donate_argnums=(0,)).lower(state_abs, batch_abs).compile()
txt = compiled.as_text()
cost = analyze_hlo(txt)
assert cost.flops_per_device > 0
assert cost.wire_bytes_per_device > 0, "expected collectives on 8 devices"
# the posit8 pod transport all-gathers uint8 codes: u8 must appear in a
# collective result type
assert any(k in cost.wire_by_kind for k in ("all-gather", "all-reduce"))
r = roofline_terms(cost.flops_per_device, cost.bytes_per_device,
                   cost.wire_bytes_per_device, 1e9, 8)
assert r["bound"] in ("compute", "memory", "collective")
# ALSO: run the compiled step on real (fake-device) inputs to prove the
# sharded program executes, not just compiles.
import numpy as np
from repro.launch.train import make_train_state
state = jax.device_put(make_train_state(model, jax.random.PRNGKey(0)), ss)
batch = {"tokens": jnp.zeros((8, 64), jnp.int32),
         "labels": jnp.zeros((8, 64), jnp.int32)}
batch = jax.device_put(batch, bs)
new_state, metrics = compiled(state, batch)
assert np.isfinite(float(metrics["loss"]))
print("OK", json.dumps({k: float(v) for k, v in r.items()
                        if isinstance(v, (int, float))}))
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=1200)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_decode_cell_subprocess_small_mesh():
    """Quantized (pofx8) decode step lowers, compiles AND RUNS sharded."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, "src")
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ARCHS, RunConfig, smoke
from repro.core.quantizers import QuantSpec, QuantizedTensor
from repro.nn.models import build_model, quantize_params
from jax.sharding import NamedSharding, PartitionSpec as P

mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = smoke(ARCHS["deepseek-67b"])
model = build_model(cfg, RunConfig(remat="none"), mesh=mesh)
spec = QuantSpec(kind="pofx", N=8, ES=2, M=8)
params = quantize_params(model.init(jax.random.PRNGKey(0)), spec)
p_shard_plain = model.param_shardings(
    jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0))))
repl = NamedSharding(mesh, P())
flat_s, td = jax.tree_util.tree_flatten(p_shard_plain, is_leaf=lambda x: x is None)
objs = td.flatten_up_to(params)
p_shard = td.unflatten([QuantizedTensor(s, repl, o.spec)
                        if isinstance(o, QuantizedTensor) else s
                        for s, o in zip(flat_s, objs)])
params = jax.device_put(params, p_shard)
B, S = 8, 64
cache = model.init_cache(B, S)
c_shard = model.cache_shardings(B, S)
cache = jax.device_put(cache, c_shard)
tok = jnp.zeros((B, 1), jnp.int32)
step = jax.jit(model.decode_step, donate_argnums=(1,),
               in_shardings=(p_shard, c_shard, None),
               out_shardings=(c_shard, None))
cache, logits = step(params, cache, tok)
cache, logits = step(params, cache, tok)
assert logits.shape == (B, cfg.padded_vocab)
assert np.all(np.isfinite(np.asarray(logits, np.float32)))
print("OK decode")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))), timeout=1200)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK decode" in r.stdout


def test_hlo_parser_on_synthetic_module():
    from repro.launch.hlo_parser import analyze_hlo
    txt = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,8]{1,0}) tuple(%ni, %d)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8,8]{1,0}) tuple(%zero, %x)
  %w = (s32[], f32[8,8]{1,0}) while(%t0), condition=%cond, body=%body
  %ag = f32[16,8]{1,0} all-gather(%x), replica_groups=[4,2]<=[8], dimensions={0}, channel_id=1
  ROOT %r = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""
    c = analyze_hlo(txt)
    assert c.flops_per_device == 5 * 2 * 8 * 8 * 8      # 5 trips x dot
    assert ("body", 5) in c.loops
    # all-gather of 16x8 f32 over group of 2: 512B * 1/2 wire
    assert abs(c.wire_bytes_per_device - 16 * 8 * 4 * 0.5) < 1e-6

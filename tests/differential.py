"""Shared differential (oracle-equality) harness for the serving engine.

Three suites pin the same contract — an engine variant must reproduce a
reference engine's token streams EXACTLY, request by request:

  * kernel vs XLA fallback      (tests/test_engine.py, tests/test_kv_cache.py)
  * quantized KV cache kernel   (tests/test_kv_cache.py)
  * tensor parallel tp=N vs 1   (tests/test_tp_engine.py)

PR 3 established the discipline for kernel-vs-fallback; this module is that
discipline promoted to one helper so every suite reports mismatches the
same way (first diverging request/step, both streams) instead of a bare
dict compare.

Also hosts the tiny request/engine builders the engine suites share (the
``tiny`` model factory itself lives in tests/conftest.py as a fixture).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.launch.engine import Request, SamplingParams, ServeEngine

__all__ = ["make_prompt", "make_request", "make_engine", "engine_tokens",
           "assert_token_identical", "differential_engines"]


def make_prompt(i: int, n: int = 8, vocab: int = 512) -> np.ndarray:
    """Deterministic per-request prompt (seeded by the request id)."""
    return np.random.RandomState(i).randint(0, vocab, n)


def make_request(i: int, vocab: int, max_new: int = 5, temp: float = 0.0,
                 top_k: int = 0, arrival: float = 0.0, n: int = 8) -> Request:
    return Request(rid=i, prompt=make_prompt(i, n, vocab), max_new=max_new,
                   sampling=SamplingParams(temperature=temp, top_k=top_k),
                   arrival=arrival)


def make_engine(model, params, **kw) -> ServeEngine:
    """Engine with the suites' shared small defaults (override per test)."""
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 48)
    kw.setdefault("chunk", 3)
    kw.setdefault("seed", 0)
    return ServeEngine(model, params, **kw)


def engine_tokens(model, params, requests: Sequence[Request],
                  **engine_kw) -> Dict[int, List[int]]:
    """Serve a workload to completion; returns {rid: generated tokens}."""
    eng = make_engine(model, params, **engine_kw)
    return {s.req.rid: s.out for s in eng.run(list(requests))}


def assert_token_identical(got: Dict[int, List[int]],
                           oracle: Dict[int, List[int]],
                           label: str = "variant",
                           oracle_label: str = "oracle") -> None:
    """Token-identity assertion with a first-divergence diagnostic."""
    assert sorted(got) == sorted(oracle), (
        f"{label} served rids {sorted(got)} but {oracle_label} served "
        f"{sorted(oracle)}")
    for rid in sorted(oracle):
        a, b = got[rid], oracle[rid]
        if a == b:
            continue
        step = next((s for s, (x, y) in enumerate(zip(a, b)) if x != y),
                    min(len(a), len(b)))
        raise AssertionError(
            f"{label} diverges from {oracle_label} on rid {rid} at token "
            f"{step}:\n  {label:>12}: {a}\n  {oracle_label:>12}: {b}")


def differential_engines(oracle: Callable[[], ServeEngine],
                         variants: Dict[str, Callable[[], ServeEngine]],
                         requests: Callable[[], List[Request]],
                         drive: Optional[Callable] = None) -> None:
    """Run the same workload through an oracle engine and each variant
    engine; every variant's token streams must be identical to the
    oracle's.

    ``drive(engine, requests)`` customizes how a workload is served (e.g.
    injecting an eviction mid-flight); the default is ``engine.run``.
    Builders construct fresh engines so donated caches never leak between
    runs, and ``requests()`` builds fresh Request lists (engines mutate
    nothing in them, but symmetry keeps workloads obviously identical).
    """
    def serve(build) -> Dict[int, List[int]]:
        eng = build()
        if drive is None:
            return {s.req.rid: s.out for s in eng.run(requests())}
        return drive(eng, requests())

    ref = serve(oracle)
    for name, build in variants.items():
        assert_token_identical(serve(build), ref, label=name)

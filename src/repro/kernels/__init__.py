"""repro.kernels — Pallas TPU kernels for the PoFx hot path.

pofx_decode:     VPU bit-parallel Algorithm-1 decode (posit codes -> FxP int8)
pofx_matmul:     fused Move&Store kernel (decode in VMEM -> MXU matmul)
fxp_matmul:      int8 x int8 -> int32 MAC (the paper's FxP baseline)
kv_flash_decode: fused quantized-KV-cache flash-decode attention (uint8/int8
                 code tiles stream from HBM, dequantize on the VPU in VMEM,
                 online-softmax against them — full-precision K/V never
                 round-trips through HBM)
kv_flash_paged_decode: the same decode indirected through a per-slot block
                 table over a flat page pool (scalar-prefetch indexing; the
                 paged serving engine's hot path, DESIGN.md §10)
ref:             pure-jnp oracles; every kernel is allclose-tested against them.

Shared helpers (used by every matmul-shaped kernel in this package):

``vmem_scratch(shape, dtype)`` allocates a VMEM scratch accumulator, and
``DEFAULT_BLOCKS`` / ``default_blocks()`` is the one (bm, bn, bk) block table
keyed by backend — MXU-aligned multiples of 128 on TPU, smaller tiles for the
CPU interpreter (less padding on smoke-sized shapes, same numerics contract).
"""
import jax as _jax
import jax.numpy as _jnp

# (bm, bn, bk) matmul block shapes per backend. TPU: multiples of 128 on
# every contracted/lane dim for MXU alignment; CPU (interpret mode) and GPU
# use smaller tiles so smoke-sized operands pad less.
DEFAULT_BLOCKS = {
    "tpu": (256, 256, 512),
    "cpu": (128, 128, 256),
    "gpu": (128, 128, 256),
}


def default_blocks(backend: str | None = None) -> tuple:
    """The (bm, bn, bk) block table entry for ``backend`` (default: the
    current jax backend; unknown backends get the TPU entry)."""
    return DEFAULT_BLOCKS.get(backend or _jax.default_backend(),
                              DEFAULT_BLOCKS["tpu"])


def vmem_scratch(shape, dtype=_jnp.float32):
    """A VMEM scratch-buffer spec for ``pl.pallas_call(scratch_shapes=...)``.

    Imported lazily so that merely importing repro.kernels never pulls the
    TPU-specific pallas module on backends that lack it.
    """
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(tuple(shape), dtype)


from .ops import fxp_matmul, pofx_decode, pofx_matmul, quant_matmul  # noqa: F401,E402
from .kv_flash_decode import kv_flash_decode  # noqa: F401,E402
from .kv_flash_paged_decode import kv_flash_paged_decode  # noqa: F401,E402

"""Architecture registry: --arch <id> resolves here."""
from . import (
    chameleon_34b,
    deepseek_67b,
    falcon_mamba_7b,
    llama3_405b,
    llama4_maverick_400b_a17b,
    moonshot_v1_16b_a3b,
    nemotron_4_340b,
    whisper_medium,
    yi_9b,
    zamba2_1_2b,
)
from .base import SHAPES, ModelConfig, RunConfig, ShapeConfig, smoke  # noqa: F401

ARCHS = {
    "llama3-405b": llama3_405b.CONFIG,
    "nemotron-4-340b": nemotron_4_340b.CONFIG,
    "yi-9b": yi_9b.CONFIG,
    "deepseek-67b": deepseek_67b.CONFIG,
    "llama4-maverick-400b-a17b": llama4_maverick_400b_a17b.CONFIG,
    "moonshot-v1-16b-a3b": moonshot_v1_16b_a3b.CONFIG,
    "chameleon-34b": chameleon_34b.CONFIG,
    "falcon-mamba-7b": falcon_mamba_7b.CONFIG,
    "whisper-medium": whisper_medium.CONFIG,
    "zamba2-1.2b": zamba2_1_2b.CONFIG,
}

# long_500k requires sub-quadratic sequence mixing (assignment): only SSM /
# hybrid archs run it; pure full-attention archs skip (see DESIGN.md).
LONG_CONTEXT_ARCHS = {"falcon-mamba-7b", "zamba2-1.2b"}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def cells():
    """All (arch, shape) dry-run cells incl. documented skips."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES.values():
            skip = ""
            if shape.name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
                skip = "full-attention arch: long_500k needs sub-quadratic mixing"
            out.append((arch, shape.name, skip))
    return out

"""Fig. 10/11: PoFx converter cost vs (N-1, ES, M).

FPGA metrics (CPD / LUTs / power) become: static op count of the vectorized
converter (LUT/depth proxy), measured decode throughput, and — matching the
paper's observation that cost is flat in M but grows with ES and N — the
trends across the sweep.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.pofx import pofx_normalized

from .common import jaxpr_ops, wall_time, write_csv


def run(smoke: bool = False):
    rows = []
    n_codes = 1 << 12 if smoke else 1 << 18
    # smoke keeps the grid corners the claims read: (4,2,*) and (7,2,*)
    for N in ((5, 8) if smoke else (5, 6, 7, 8)):
        for ES in ((2,) if smoke else (0, 1, 2, 3)):
            codes = jnp.asarray(
                np.random.default_rng(N * 10 + ES).integers(0, 1 << (N - 1),
                                                            n_codes),
                jnp.int32)
            for M in (8, 16):
                fn = lambda c: pofx_normalized(c, N, ES, M)[0]
                rows.append({
                    "N_minus_1": N - 1, "ES": ES, "M": M,
                    "ops": jaxpr_ops(fn, codes),
                    "ns_per_code": wall_time(fn, codes) / n_codes * 1e9,
                })
    write_csv("fig10_pofx", rows)
    by = {(r["N_minus_1"], r["ES"], r["M"]): r for r in rows}
    # paper trends: cost flat in M; grows with N and ES
    flat_in_m = abs(by[(7, 2, 16)]["ops"] - by[(7, 2, 8)]["ops"]) <= 2
    grows_with_n = by[(7, 2, 8)]["ops"] >= by[(4, 2, 8)]["ops"]
    return rows, {"flat_in_M": flat_in_m, "grows_with_N": grows_with_n}

"""zamba2-1.2b [hybrid]: 38L mamba2 d_model=2048 + ONE shared attention
block (32H kv=32, d_ff=8192) applied every 6 ssm layers, ssm_state=64
[arXiv:2411.15242]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab_size=32000, act="gelu_plain",
    ssm_state=64, d_inner=4096, conv_width=4, ssm_head_dim=64, ssm_chunk=128,
    attn_every=6, rope_theta=10000.0,
)

"""Continuous-batching engine + quantized-matmul scale-layout tests.

Covers the serving engine (scheduler invariants, scan-decode vs per-step
bit-equality, eviction/resume, EOS stopping, engine determinism, the
weight-kernel differential in Pallas interpret mode) and the scale-layout
guards in matmul_param/quant_matmul (regression for the silent row-0
truncation of contraction-varying scales).

Tiny models come from the session ``tiny`` fixture (tests/conftest.py);
request/engine builders and the oracle-equality assertions are shared with
the KV and TP suites via tests/differential.py.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from differential import (differential_engines, make_engine as _engine,
                          make_prompt as _prompt, make_request as _req)
from repro.configs import RunConfig
from repro.core.quantizers import QuantSpec, QuantizedTensor, dequantize, quantize
from repro.kernels.ops import out_channel_scale, quant_matmul
from repro.launch.engine import (Request, SamplingParams, ServeEngine,
                                 sample_tokens)
from repro.nn.layers import matmul_param
from repro.nn.models import apply_policy


@pytest.fixture(scope="module")
def dense(tiny):
    return tiny("yi-9b")


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------


def test_scheduler_admit_finish_invariants(dense):
    cfg, model, params = dense
    eng = _engine(model, params)
    reqs = [_req(i, cfg.vocab_size, max_new=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    finished = []
    while eng.pending_rids or eng.active_rids:
        eng.admit_ready()
        active, pending = eng.active_rids, eng.pending_rids
        # invariants: a rid is in at most one place; slots are conserved
        assert len(set(active)) == len(active)
        assert not (set(active) & set(pending))
        assert len(active) + len(eng.free_slots) == eng.n_slots
        assert len(active) <= eng.n_slots
        finished += eng.step()
    assert sorted(s.req.rid for s in finished) == [0, 1, 2, 3, 4]
    for s in finished:
        assert s.finish_reason == "length"
        assert len(s.out) == 4
        assert s.slot == -1


def test_submit_validation(dense):
    cfg, model, params = dense
    eng = _engine(model, params, max_len=16)
    with pytest.raises(ValueError, match="prompt length"):
        eng.submit(_req(0, cfg.vocab_size, n=16))
    with pytest.raises(ValueError, match="max_new"):
        eng.submit(Request(1, _prompt(1), max_new=0))
    eng.submit(_req(2, cfg.vocab_size, n=4))
    with pytest.raises(ValueError, match="duplicate"):
        eng.submit(_req(2, cfg.vocab_size, n=4))


def test_max_new_clamped_to_cache_room(dense):
    cfg, model, params = dense
    eng = _engine(model, params, max_len=12, n_slots=1)
    done = eng.run([_req(0, cfg.vocab_size, max_new=50, n=8)])
    assert len(done[0].out) == 4  # 12 - 8: decode never writes past max_len


def test_evict_readmit_resumes_identically(dense):
    cfg, model, params = dense
    reqs = lambda: [_req(i, cfg.vocab_size, max_new=7, temp=0.7, top_k=8)
                    for i in range(3)]
    ref = {s.req.rid: s.out
           for s in _engine(model, params, chunk=3).run(reqs())}

    eng = _engine(model, params, chunk=3)
    for r in reqs():
        eng.submit(r)
    eng.admit_ready()
    eng.step()
    victim = eng.active_rids[0]
    eng.evict(victim)
    assert victim not in eng.active_rids
    assert eng.pending_rids[0] == victim
    assert len(eng.free_slots) == 1
    while eng.pending_rids or eng.active_rids:
        eng.admit_ready()
        eng.step()
    got = {rid: st.out for rid, st in eng._states.items()}
    # resumed request: identical sample stream (keys fold absolute positions)
    assert got == ref
    assert eng._states[victim].n_evictions == 1
    # decode-token accounting: one prefill-sampled token per ADMISSION
    # (3 requests + 1 resume), the rest decode-generated
    assert eng.n_prefill_sampled == 4
    st = eng.stats()
    assert st["decode_tokens"] == st["generated_tokens"] - 4


def test_admit_skips_unarrived_queue_head(dense):
    # regression: a not-yet-arrived head must not livelock run() when an
    # already-arrived request sits behind it in a manually-built queue
    cfg, model, params = dense
    eng = _engine(model, params)
    eng.submit(_req(0, cfg.vocab_size, max_new=2, arrival=50.0))
    eng.submit(_req(1, cfg.vocab_size, max_new=2, arrival=0.0))
    done = eng.run([])
    assert sorted(s.req.rid for s in done) == [0, 1]
    assert eng._states[1].admitted_at < eng._states[0].admitted_at


# ---------------------------------------------------------------------------
# Scan decode == per-step decode
# ---------------------------------------------------------------------------


def test_scan_decode_bit_identical_to_per_step(dense):
    """The scan-fused chunk must be bit-identical to dispatching
    model.decode_step + sampling one step at a time."""
    cfg, model, params = dense
    steps = 6
    eng = _engine(model, params, chunk=steps)
    for i in range(2):
        eng.submit(_req(i, cfg.vocab_size, max_new=steps + 1, temp=0.5,
                        top_k=16))
    eng.admit_ready()

    # reference FIRST (eng.step donates the cache buffers)
    decode = jax.jit(model.decode_step)
    cache = jax.tree.map(lambda x: x, eng.cache)
    tok = eng._tok
    ref_toks = []
    for _ in range(steps):
        pos = cache["pos"]
        cache, logits = decode(params, cache, tok)
        keys = jax.vmap(jax.random.fold_in)(eng._keys, pos)
        nxt = sample_tokens(logits, keys,
                            jnp.full((2,), 0.5, jnp.float32),
                            jnp.full((2,), 16, jnp.int32))
        ref_toks.append(np.asarray(nxt))
        tok = nxt[:, None]
        cache = dict(cache, pos=pos + 1)
    ref = np.stack(ref_toks)

    out = {s.req.rid: s.out for s in [st for st in eng.step(steps)]}
    for rid, gen in out.items():
        # gen[0] came from prefill; gen[1:] are the scan-decode tokens
        np.testing.assert_array_equal(np.asarray(gen[1:]), ref[:, rid],
                                      err_msg=f"rid {rid}")


def test_chunk_size_and_slot_count_invariance(dense):
    cfg, model, params = dense
    mk = lambda: [_req(i, cfg.vocab_size, max_new=6, temp=0.7, top_k=8,
                       arrival=float(i)) for i in range(3)]
    outs = []
    for slots, chunk in ((2, 1), (2, 5), (3, 4), (1, 4)):
        eng = _engine(model, params, n_slots=slots, chunk=chunk)
        outs.append({s.req.rid: s.out for s in eng.run(mk())})
    assert all(o == outs[0] for o in outs[1:])


# ---------------------------------------------------------------------------
# Stopping and sampling
# ---------------------------------------------------------------------------


def test_eos_stops_slot(dense):
    cfg, model, params = dense
    base = _engine(model, params).run([_req(0, cfg.vocab_size, max_new=5)])
    full = base[0].out
    eos = full[2]
    done = _engine(model, params, eos_id=eos).run(
        [_req(0, cfg.vocab_size, max_new=5)])
    assert done[0].finish_reason == "eos"
    assert done[0].out == full[:3]  # the EOS itself is emitted, then stop


def test_sample_tokens_semantics():
    logits = jnp.asarray(np.random.RandomState(0).normal(size=(3, 32)),
                         jnp.float32)
    keys = jax.vmap(jax.random.fold_in, (None, 0))(
        jax.random.PRNGKey(0), jnp.arange(3))
    argmax = np.asarray(jnp.argmax(logits, axis=-1))
    # temperature 0 -> greedy, key-independent
    np.testing.assert_array_equal(
        np.asarray(sample_tokens(logits, keys, jnp.zeros(3), jnp.zeros(3, jnp.int32))),
        argmax)
    # top_k=1 -> greedy even at high temperature
    np.testing.assert_array_equal(
        np.asarray(sample_tokens(logits, keys, jnp.full(3, 5.0),
                                 jnp.ones(3, jnp.int32))),
        argmax)
    # top_k masks everything outside the k best
    top2 = np.argsort(np.asarray(logits), axis=-1)[:, -2:]
    for trial in range(8):
        k2 = jax.vmap(jax.random.fold_in, (None, 0))(
            jax.random.PRNGKey(trial), jnp.arange(3))
        got = np.asarray(sample_tokens(logits, k2, jnp.full(3, 2.0),
                                       jnp.full(3, 2, jnp.int32)))
        for b in range(3):
            assert got[b] in top2[b]
    # mixed per-slot params in one batch: slot 0 greedy, others sampled
    mixed = np.asarray(sample_tokens(
        logits, keys, jnp.asarray([0.0, 1.0, 1.0]), jnp.zeros(3, jnp.int32)))
    assert mixed[0] == argmax[0]


# ---------------------------------------------------------------------------
# Bucketed prefill
# ---------------------------------------------------------------------------


def test_prefill_length_matches_exact(dense):
    cfg, model, params = dense
    toks = jnp.asarray(_prompt(0, 6, cfg.vocab_size))[None]
    cache_a = model.init_cache(1, 32)
    _, lg_exact = model.prefill(params, toks, cache=cache_a)
    padded = jnp.pad(toks, ((0, 0), (0, 10)))
    cache_b = model.init_cache(1, 32)
    cache_b, lg_pad = model.prefill(params, padded, cache=cache_b,
                                    length=jnp.asarray([6]))
    np.testing.assert_allclose(np.asarray(lg_pad, np.float32),
                               np.asarray(lg_exact, np.float32),
                               atol=2e-2, rtol=1e-2)
    assert np.asarray(cache_b["pos"]).tolist() == [6]


def test_engine_prompt_bucket_matches_exact(dense):
    cfg, model, params = dense
    mk = lambda: [_req(i, cfg.vocab_size, max_new=4, n=5 + i)
                  for i in range(2)]
    a = {s.req.rid: s.out
         for s in _engine(model, params).run(mk())}
    b = {s.req.rid: s.out
         for s in _engine(model, params, prompt_bucket=8).run(mk())}
    assert a == b


def test_prompt_bucket_clamped_to_max_len(dense):
    # bucket-rounded prefill length must not exceed the cache (regression:
    # Pb=16 > max_len=15 crashed inside write_kv with a shape error)
    cfg, model, params = dense
    eng = _engine(model, params, max_len=15, n_slots=1, prompt_bucket=16)
    done = eng.run([_req(0, cfg.vocab_size, max_new=2, n=13)])
    assert len(done[0].out) == 2


def test_prefill_length_rejected_for_ssm(tiny):
    cfg, model, params = tiny("falcon-mamba-7b")
    toks = jnp.asarray(_prompt(0, 8, cfg.vocab_size))[None]
    with pytest.raises(ValueError, match="SSM"):
        model.prefill(params, toks, cache=model.init_cache(1, 16),
                      length=jnp.asarray([4]))
    with pytest.raises(ValueError, match="prompt_bucket"):
        ServeEngine(model, params, n_slots=1, max_len=16, prompt_bucket=4)


# ---------------------------------------------------------------------------
# Other families through the engine (cache scatter generality)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "moonshot-v1-16b-a3b",
                                  "zamba2-1.2b"])
def test_engine_other_families(tiny, arch):
    cfg, model, params = tiny(arch)
    eng = ServeEngine(model, params, n_slots=2, max_len=24, chunk=3)
    done = eng.run([_req(i, cfg.vocab_size, max_new=4, arrival=float(2 * i))
                    for i in range(3)])
    for s in done:
        assert len(s.out) == 4
        assert all(0 <= t < cfg.padded_vocab for t in s.out)


def test_engine_rejects_encdec(tiny):
    cfg, model, params = tiny("whisper-medium")
    with pytest.raises(NotImplementedError):
        ServeEngine(model, params, n_slots=1, max_len=16)


# ---------------------------------------------------------------------------
# Determinism regression (the position-folded key scheme from PR 2)
# ---------------------------------------------------------------------------


def test_engine_determinism_across_fresh_instances(dense):
    """Same seed + same arrival order => bit-identical sampled tokens
    across two FRESH engine instances. Guards the position-folded slot-key
    scheme: a key stream that depended on any transient (wall time, object
    ids, admission history) instead of (seed, rid, absolute position)
    would break replayability of a served workload."""
    cfg, model, params = dense
    mk = lambda: [_req(i, cfg.vocab_size, max_new=6, temp=0.8, top_k=4,
                       arrival=float(i)) for i in range(4)]
    runs = []
    for _ in range(2):
        eng = _engine(model, params, n_slots=2, chunk=3, seed=7)
        runs.append({s.req.rid: s.out for s in eng.run(mk())})
    assert runs[0] == runs[1]
    # a different engine seed must change the sampled streams (the test
    # above would pass vacuously if sampling ignored the seed entirely)
    other = _engine(model, params, n_slots=2, chunk=3, seed=8)
    assert {s.req.rid: s.out for s in other.run(mk())} != runs[0]


# ---------------------------------------------------------------------------
# Weight-kernel differential + serving smoke (Pallas interpret mode on CPU)
# ---------------------------------------------------------------------------


def test_engine_greedy_token_identical_weight_kernel_vs_lut(tiny):
    """The fused Pallas PoFx matmul kernels must serve token-identical to
    the XLA LUT fallback at the same quantized weights — the weight-path
    member of the differential family (tests/differential.py) next to the
    KV-kernel and TP suites. f32 activations: the kernel's tiled f32
    accumulation reorders sums vs the fallback dot, and bf16 rounding
    would make token-identity precision-flaky rather than meaningful."""
    rcfg = RunConfig(remat="none", activation_dtype="f32")
    cfg, lut, params = tiny("yi-9b", rcfg=rcfg)
    params = apply_policy(params, "pofx8")
    _, kern, _ = tiny("yi-9b", rcfg=rcfg, use_kernel=True)
    differential_engines(
        oracle=lambda: _engine(lut, params, max_len=24),
        variants={"pallas": lambda: _engine(kern, params, max_len=24)},
        requests=lambda: [_req(i, cfg.vocab_size, max_new=4, n=6)
                          for i in range(2)])


def test_use_kernel_serving_smoke(tiny):
    cfg, model, params = tiny("yi-9b", use_kernel=True)
    params = apply_policy(params, "pofx8")
    eng = ServeEngine(model, params, n_slots=2, max_len=16, chunk=2)
    done = eng.run([_req(i, cfg.vocab_size, max_new=3, n=6)
                    for i in range(2)])
    for s in done:
        assert len(s.out) == 3
        assert all(0 <= t < cfg.padded_vocab for t in s.out)


# ---------------------------------------------------------------------------
# Scale-layout guards (regression: contraction-varying scales corrupted
# output silently instead of raising)
# ---------------------------------------------------------------------------


def test_out_channel_scale_layouts():
    codes_shape = (16, 4, 8)
    for shape in ((), (1,), (8,), (1, 1, 8), (1, 4, 8), (1, 4, 1)):
        s = out_channel_scale(jnp.ones(shape), codes_shape)
        assert s.shape == (1, 32)
    with pytest.raises(ValueError, match="contraction"):
        out_channel_scale(jnp.ones((16, 1, 1)), codes_shape)
    with pytest.raises(ValueError, match="rank"):
        out_channel_scale(jnp.ones((1, 16, 4, 8)), codes_shape)
    with pytest.raises(ValueError, match="broadcast"):
        out_channel_scale(jnp.ones((3, 8)), codes_shape)


def test_matmul_param_rejects_contraction_varying_scale():
    w = np.random.RandomState(0).normal(size=(16, 8)).astype(np.float32)
    qt = quantize(jnp.asarray(w), QuantSpec(kind="pofx", N=8, ES=2), axis=-1)
    x = jnp.asarray(np.random.RandomState(1).normal(size=(2, 16)), jnp.float32)
    # valid per-output-channel scale: matches the dequantize reference
    y = matmul_param(x, qt)
    ref = jnp.dot(x.astype(jnp.float32),
                  dequantize(qt, jnp.float32))
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(ref),
                               atol=1e-2, rtol=1e-2)
    # per-input-channel scale (varies along the contraction axis): raise,
    # don't silently keep row 0
    bad = QuantizedTensor(qt.codes, jnp.ones((16, 1), jnp.float32), qt.spec)
    with pytest.raises(ValueError, match="contraction"):
        matmul_param(x, bad)
    # 3-D weights with a stacked scale over the contraction axis
    w3 = np.random.RandomState(2).normal(size=(16, 2, 4)).astype(np.float32)
    qt3 = quantize(jnp.asarray(w3), QuantSpec(kind="fxp", M=8, F=7), axis=-1)
    assert matmul_param(x, qt3).shape == (2, 2, 4)
    bad3 = QuantizedTensor(qt3.codes, jnp.ones((16, 1, 1), jnp.float32),
                           qt3.spec)
    with pytest.raises(ValueError, match="contraction"):
        matmul_param(x, bad3)


@pytest.mark.parametrize("kind", ["pofx", "fxp"])
def test_quant_matmul_kernel_rejects_bad_scale(kind):
    spec = (QuantSpec(kind="pofx", N=8, ES=2) if kind == "pofx"
            else QuantSpec(kind="fxp", M=8, F=7))
    w = np.random.RandomState(0).normal(size=(16, 8)).astype(np.float32)
    qt = quantize(jnp.asarray(w), spec, axis=-1)
    x = jnp.asarray(np.random.RandomState(1).normal(size=(2, 16)), jnp.float32)
    ok = quant_matmul(x, qt, use_kernel=True)
    ref = jnp.dot(x, dequantize(qt, jnp.float32))
    np.testing.assert_allclose(np.asarray(ok, np.float32), np.asarray(ref),
                               atol=0.35, rtol=0.1)
    bad = QuantizedTensor(qt.codes, jnp.ones((16, 1), jnp.float32), qt.spec)
    with pytest.raises(ValueError, match="contraction"):
        quant_matmul(x, bad, use_kernel=True)

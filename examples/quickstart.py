"""Quickstart: the paper's pipeline in 60 lines.

1. take float weights,
2. store them as (N-1)-bit normalized posit codes (ExPAN(N)D's format),
3. run a matmul through the PoFx datapath (decode -> FxP -> MXU),
4. compare against fp32 and against FxP8 storage.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import parse_spec
from repro.core.quantizers import quantize, storage_bits
from repro.kernels.ops import quant_matmul

rng = np.random.default_rng(0)
w = jnp.asarray(rng.normal(0, 0.05, (512, 256)), jnp.float32)   # trained-ish
x = jnp.asarray(rng.normal(0, 1.0, (8, 512)), jnp.float32)

y_ref = x @ w

print(f"{'format':<14} {'bits/w':>7} {'storage':>10} {'matmul rel err':>15}")
for name in ["fxp8", "posit8es2", "pofx8es2", "pofx6es2"]:   # pofx8es2: paper
    qt = quantize(w, parse_spec(name), axis=-1)
    y = quant_matmul(x, qt, out_dtype=jnp.float32)
    rel = float(jnp.linalg.norm(y - y_ref) / jnp.linalg.norm(y_ref))
    bits = storage_bits(qt) / w.size
    print(f"{name:<14} {bits:7.2f} {storage_bits(qt)/8/1024:8.1f}KiB {rel:15.5f}")

# the same QuantizedTensor flows through jit / scan / checkpointing:
qt = quantize(w, parse_spec("pofx8es2"), axis=-1)
fast = jax.jit(lambda x, q: quant_matmul(x, q))
print("jit ok:", fast(x, qt).shape, "codes dtype:", qt.codes.dtype)

"""Trip-count-aware cost model over post-SPMD HLO text.

XLA's ``compiled.cost_analysis()`` visits every computation exactly once —
a ``jax.lax.scan`` of 126 layers reports the FLOPs of *one* layer (verified
empirically; see EXPERIMENTS.md §Roofline methodology). For a roofline that
is useless, so this module re-derives the three terms from the HLO text
with exact loop accounting:

  1. split the module into computations; classify each by how it is
     referenced (entry / while body / while cond / fusion ``calls=`` /
     ``to_apply`` helper / conditional branch),
  2. read every while loop's trip count out of its condition computation
     (the ``constant(N)`` compared against the induction variable),
  3. propagate multipliers down the call tree (a dot inside a fusion inside
     a layer-scan inside a microbatch-scan gets n_layers x n_micro),
  4. cost model per op:
       flops:  dot = 2 * result_elems * prod(contracting dims)
       bytes:  top-level ops in entry/while bodies: operands + result,
               with in-place semantics for dynamic-update-slice (2x update
               slice) and gather/dynamic-slice (2x result + indices) — the
               HBM-traffic view, not buffer-assignment capacity,
       wire:   collectives with ring-algorithm bytes-on-wire (x multiplier).

This replaces the depth-heuristic in hlo_analysis.parse_collectives with
exact trip counts read from the loops themselves.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCost"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_CFG = re.compile(r'known_trip_count[^0-9]*"n":"(\d+)"')
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(?P<type>\([^()]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<inst>[a-z][a-z0-9\-]*)\((?P<operands>[^)]*)\)(?P<attrs>.*)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_REF = re.compile(r"%([\w.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")

# ops whose operand/result buffers are aliased or free — no HBM traffic
_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "after-all", "partition-id",
               "replica-id", "iota", "rng-bit-generator"}

_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start", "all-reduce-start",
                "collective-permute-start"}


def _shape_info(type_str: str) -> Tuple[int, List[List[int]]]:
    """(total bytes, list of dims lists) for a possibly-tuple type."""
    total = 0
    shapes = []
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        ds = [int(d) for d in dims.split(",") if d]
        n = 1
        for d in ds:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        shapes.append(ds)
    return total, shapes


@dataclasses.dataclass
class _Op:
    name: str
    inst: str
    type_str: str
    operands: List[str]
    raw_operands: str
    attrs: str
    bytes_: int
    shapes: List[List[int]]


@dataclasses.dataclass
class _Comp:
    name: str
    is_entry: bool
    ops: List[_Op]
    table: Dict[str, _Op]


def _parse_computations(text: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    cur: Optional[_Comp] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m:
                cur = _Comp(m.group(2), bool(m.group(1)), [], {})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LINE.match(line)
        if not m:
            continue
        bytes_, shapes = _shape_info(m.group("type"))
        op = _Op(m.group(1), m.group("inst"), m.group("type"),
                 _REF.findall(m.group("operands")), m.group("operands"),
                 m.group("attrs"), bytes_, shapes)
        cur.ops.append(op)
        cur.table[op.name] = op
    return comps


def _ref_attr(attrs: str, key: str) -> List[str]:
    out = []
    for m in re.finditer(key + r"=%?([\w.\-]+)", attrs):
        out.append(m.group(1))
    m = re.search(key + r"=\{([^}]*)\}", attrs)
    if m:
        out.extend(_REF.findall(m.group(1)))
    return out


def _cond_trip(cond_lines: List[_Op]) -> int:
    """Trip count = the integer constant the induction variable is compared
    against in the loop condition (scan emits `compare(i, constant(L))`)."""
    ints = []
    for op in cond_lines:
        if op.inst != "constant":
            continue
        m = re.match(r"\s*(\d+)\s*$", op.raw_operands)
        if m:
            ints.append(int(m.group(1)))
    return max(ints) if ints else 1


@dataclasses.dataclass
class HloCost:
    flops_per_device: float
    bytes_per_device: float
    wire_bytes_per_device: float
    wire_by_kind: Dict[str, float]
    loops: List[Tuple[str, int]]          # (body computation, trip count)
    n_collectives: int

    def summary(self) -> str:
        rows = [f"  flops/device      {self.flops_per_device/1e12:10.3f} T",
                f"  bytes/device      {self.bytes_per_device/2**30:10.2f} GiB",
                f"  wire bytes/device {self.wire_bytes_per_device/2**30:10.3f} GiB"]
        for k, v in sorted(self.wire_by_kind.items()):
            rows.append(f"    {k:18s} {v/2**30:10.3f} GiB")
        rows.append("  loops: " + ", ".join(f"{n}x{t}" for n, t in self.loops[:8]))
        return "\n".join(rows)


def _dot_flops(op: _Op, table: Dict[str, _Op]) -> float:
    _, res_shapes = _shape_info(op.type_str)
    if not res_shapes:
        return 0.0
    res_elems = 1
    for d in res_shapes[0]:
        res_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    contract = 1
    if m and op.operands:
        lhs = table.get(op.operands[0])
        if lhs is not None and lhs.shapes:
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(lhs.shapes[0]):
                    contract *= lhs.shapes[0][int(idx)]
    return 2.0 * res_elems * contract


def _op_traffic(op: _Op, table: Dict[str, _Op],
                dus_fusions: Optional[set] = None) -> float:
    """HBM bytes for one top-level op (read operands + write result).

    In-place semantics: dynamic-update-slice — bare or as a fusion whose
    root is one (XLA's in-place DUS fusion; the aliased big operand is not
    rewritten) — costs 2x the update slice, i.e. everything but the
    largest operand.
    """
    if op.inst in _NO_TRAFFIC:
        return 0.0
    if op.inst == "dynamic-update-slice":
        upd = table.get(op.operands[1]) if len(op.operands) > 1 else None
        return 2.0 * (upd.bytes_ if upd else 0)
    if op.inst in ("dynamic-slice", "gather"):
        return 2.0 * op.bytes_
    operand_bytes = [table[o].bytes_ for o in op.operands if o in table]
    if op.inst == "fusion" and dus_fusions:
        called = _ref_attr(op.attrs, "calls")
        if called and called[0] in dus_fusions and operand_bytes:
            return 2.0 * (sum(operand_bytes) - max(operand_bytes))
    return float(op.bytes_) + sum(operand_bytes)


def _wire_bytes(op: _Op) -> Tuple[str, float]:
    kind = op.inst.replace("-start", "")
    bytes_ = op.bytes_
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.attrs)
    if m:
        g = int(m.group(2))
    else:
        m = re.search(r"replica_groups=\{\{([0-9,]*)\}", op.attrs)
        g = len(m.group(1).split(",")) if m else 2
    if g <= 1:
        return kind, 0.0
    if kind == "all-gather":
        wire = bytes_ * (g - 1) / g
    elif kind == "all-reduce":
        wire = 2 * bytes_ * (g - 1) / g
    elif kind == "reduce-scatter":
        wire = bytes_ * (g - 1)
    elif kind == "all-to-all":
        wire = bytes_ * (g - 1) / g
    else:  # collective-permute
        wire = bytes_
    return kind, wire


def analyze_hlo(text: str) -> HloCost:
    comps = _parse_computations(text)

    # classify references
    fusion_calls: Dict[str, List[str]] = {}   # parent -> fused comps
    helpers = set()
    whiles: List[Tuple] = []   # (parent, body, cond, trip_from_cfg)
    branches: Dict[str, List[str]] = {}
    for comp in comps.values():
        for op in comp.ops:
            if op.inst == "fusion":
                for c in _ref_attr(op.attrs, "calls"):
                    fusion_calls.setdefault(comp.name, []).append(c)
            for c in _ref_attr(op.attrs, "to_apply"):
                helpers.add(c)
            if op.inst == "while":
                body = _ref_attr(op.attrs, "body")
                cond = _ref_attr(op.attrs, "condition")
                if body and cond:
                    m = _TRIP_CFG.search(op.attrs)
                    trip = int(m.group(1)) if m else None
                    whiles.append((comp.name, body[0], cond[0], trip))
            if op.inst == "conditional":
                for key in ("branch_computations", "true_computation",
                            "false_computation"):
                    branches.setdefault(comp.name, []).extend(
                        _ref_attr(op.attrs, key))

    # multipliers via BFS from entry
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    mult: Dict[str, float] = {}
    if entry:
        mult[entry] = 1.0
    loops: List[Tuple[str, int]] = []
    changed = True
    while changed:
        changed = False
        for parent, body, cond, trip_cfg in whiles:
            if parent in mult and body not in mult:
                trip = trip_cfg if trip_cfg is not None else (
                    _cond_trip(comps[cond].ops) if cond in comps else 1)
                mult[body] = mult[parent] * max(trip, 1)
                loops.append((body, trip))
                changed = True
        for parent, fused in fusion_calls.items():
            for c in fused:
                if parent in mult and c not in mult:
                    mult[c] = mult[parent]
                    changed = True
        for parent, brs in branches.items():
            for c in brs:
                if parent in mult and c not in mult:
                    mult[c] = mult[parent]
                    changed = True

    cond_names = {c for _, _, c, _ in whiles}
    fused_names = {c for v in fusion_calls.values() for c in v}
    # fusions that update a buffer in place: they contain a
    # dynamic-update-slice and their result is the same size as their
    # largest input (XLA aliases it; only the slice is written)
    dus_fusions = set()
    for name in fused_names:
        comp = comps.get(name)
        if comp and any(o.inst == "dynamic-update-slice" for o in comp.ops):
            dus_fusions.add(name)

    flops = 0.0
    traffic = 0.0
    wire = 0.0
    wire_by_kind: Dict[str, float] = {}
    n_coll = 0
    for comp in comps.values():
        m = mult.get(comp.name)
        if m is None:
            continue  # unreachable / helper-only
        toplevel = (comp.is_entry
                    or (comp.name not in fused_names
                        and comp.name not in helpers
                        and comp.name not in cond_names))
        for op in comp.ops:
            if op.inst in ("dot", "convolution"):
                flops += m * _dot_flops(op, comp.table)
            if not toplevel:
                continue
            if op.inst in _COLLECTIVES:
                kind, w = _wire_bytes(op)
                wire += m * w
                wire_by_kind[kind] = wire_by_kind.get(kind, 0.0) + m * w
                n_coll += 1
                traffic += m * 2 * op.bytes_
                continue
            traffic += m * _op_traffic(op, comp.table, dus_fusions)
    return HloCost(flops, traffic, wire, wire_by_kind, loops, n_coll)

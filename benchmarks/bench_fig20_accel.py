"""Figs. 20-22: accelerator deployment modes for a fully-connected layer.

The paper's four designs on the (64 x 10) weight-stationary FC accelerator,
re-expressed in TPU currency:

  Posit          store+compute posit: decode EVERY MAC operand (cost model:
                 decode ops x MACs; the FPGA's posit-ALU overhead)
  PoFx(Move)     weights MOVE as Posit(N-1), converted once, STORED FxP(8):
                 wire bits = N-1/weight, local storage = 8 bits/weight,
                 zero per-step conversion
  PoFx(Move&Store) weights move AND stay Posit(N-1); PoFx in the MAC loop:
                 wire = storage = N-1 bits, decode per use (fused Pallas
                 kernel on TPU — measured here in interpret mode)
  FxP(8)         everything 8-bit fixed point (baseline)

Storage/communication columns are exact bit counts on the real tensors;
compute overhead is measured wall-time of the XLA/Pallas paths.
Also re-states the paper's win at LM scale: HBM weight-bytes per decode
step for the assigned archs (from their configs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS
from repro.core.quantizers import QuantSpec, quantize
from repro.kernels.ops import quant_matmul
from repro.kernels.pofx_matmul import pofx_matmul

from .common import wall_time, write_csv


def run(smoke: bool = False):
    rng = np.random.default_rng(0)
    # the paper's accelerator + 1000 acts (smoke: fewer activations only —
    # the bit-accounting columns are size-exact either way)
    K, N_out, B = 64, 10, (128 if smoke else 1000)
    w = jnp.asarray(rng.normal(0, 0.1, (K, N_out)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1.0, (B, K)), jnp.float32)
    spec = QuantSpec(kind="pofx", N=6, ES=0, M=8)     # paper Fig 20 config
    qt = quantize(w, spec, axis=-1)
    n_w = K * N_out

    rows = []
    # exact bit accounting per design
    designs = {
        "posit(6,0)": {"wire_bits": 6 * n_w, "store_bits": 6 * n_w,
                       "per_mac_decode": True},
        "pofx_move(5,0)": {"wire_bits": 5 * n_w, "store_bits": 8 * n_w,
                           "per_mac_decode": False},
        "pofx_move_store(5,0)": {"wire_bits": 5 * n_w, "store_bits": 5 * n_w,
                                 "per_mac_decode": True},
        "fxp8": {"wire_bits": 8 * n_w, "store_bits": 8 * n_w,
                 "per_mac_decode": False},
    }
    # measured compute paths
    t_xla_deq = wall_time(lambda: quant_matmul(x, qt), reps=5)     # Move
    scale = jnp.broadcast_to(qt.scale, (1, N_out)).reshape(-1)
    t_fused = wall_time(lambda: pofx_matmul(
        x, qt.codes.astype(jnp.int32), scale, spec.N, spec.ES, spec.M,
        interpret=True), reps=2)                                    # Move&Store
    wq = qt.dequantize(jnp.float32)
    t_plain = wall_time(lambda: x @ wq, reps=5)                     # FxP local

    for name, d in designs.items():
        rows.append({
            "design": name,
            "wire_bits_per_weight": d["wire_bits"] / n_w,
            "store_bits_per_weight": d["store_bits"] / n_w,
            "storage_vs_fxp8_pct": 100.0 * (1 - d["store_bits"] / (8 * n_w)),
        })
    write_csv("fig20_accel", rows)

    # LM-scale restatement: weight HBM bytes per decode step by format
    lm_rows = []
    for arch in ("llama3-405b", "yi-9b", "llama4-maverick-400b-a17b"):
        cfg = ARCHS[arch]
        n_active = cfg.active_param_count()
        for fmt, bits in (("bf16", 16), ("fxp8/int8", 8), ("pofx(7,2)", 7),
                          ("pofx(5,2)", 5)):
            lm_rows.append({"arch": arch, "format": fmt,
                            "weight_GiB_per_decode_step":
                                n_active * bits / 8 / 2**30})
    write_csv("fig20_lm_restatement", lm_rows)

    move_store = designs["pofx_move_store(5,0)"]
    fxp = designs["fxp8"]
    return rows + lm_rows, {
        "storage_reduction_vs_fxp8_pct":
            100.0 * (1 - move_store["store_bits"] / fxp["store_bits"]),
        # paper: ~46% with LUTRAM granularity; pure bits: 37.5%
        "claim_ge_37pct_storage_reduction":
            (1 - move_store["store_bits"] / fxp["store_bits"]) >= 0.375,
        "t_move_xla_s": t_xla_deq,
        "t_move_store_fused_interpret_s": t_fused,
        "t_fxp_local_s": t_plain,
    }

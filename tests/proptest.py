"""Lightweight property-based testing harness.

``hypothesis`` is not installed in this offline container, so this module
provides the subset we need: seeded random strategies, a ``given``-style
decorator running N examples, and greedy shrinking of failing array inputs
(toward zeros / smaller magnitude) so failures are reported minimally.
"""
from __future__ import annotations

import functools
import itertools
from typing import Callable, Sequence

import numpy as np


class Strategy:
    def sample(self, rng: np.random.Generator):
        raise NotImplementedError

    def shrink(self, value):
        """Yield simpler candidate values (possibly none)."""
        return iter(())


class Floats(Strategy):
    def __init__(self, lo=-1e4, hi=1e4, shape=(64,), special: bool = True):
        self.lo, self.hi, self.shape, self.special = lo, hi, shape, special

    def sample(self, rng):
        x = rng.uniform(self.lo, self.hi, size=self.shape)
        # mix in magnitudes across many scales (log-uniform) + specials,
        # clipped back into [lo, hi]
        logs = np.exp2(rng.uniform(-24, 12, size=self.shape)) * rng.choice([-1, 1], self.shape)
        mask = rng.random(self.shape) < 0.5
        x = np.where(mask, logs, x)
        if self.special and x.size >= 4:
            flat = x.reshape(-1)
            flat[0] = 0.0
            flat[1] = self.hi
            flat[2] = self.lo
            flat[3] = float(2.0 ** int(rng.integers(-20, 20)))
        return np.clip(x, self.lo, self.hi).astype(np.float64)

    def shrink(self, value):
        v = np.asarray(value)
        if np.count_nonzero(v) > 0:
            yield np.zeros_like(v)
            yield v / 2.0
            half = v.copy().reshape(-1)
            half[: half.size // 2] = 0
            yield half.reshape(v.shape)


class Ints(Strategy):
    def __init__(self, lo, hi, shape=(64,)):
        self.lo, self.hi, self.shape = lo, hi, shape

    def sample(self, rng):
        return rng.integers(self.lo, self.hi, size=self.shape, endpoint=True)

    def shrink(self, value):
        v = np.asarray(value)
        if np.any(v != self.lo):
            yield np.full_like(v, self.lo)
            yield np.maximum(v // 2, self.lo)


class Choice(Strategy):
    def __init__(self, options: Sequence):
        self.options = list(options)

    def sample(self, rng):
        return self.options[int(rng.integers(len(self.options)))]


def given(seed: int = 0, examples: int = 50, **strategies: Strategy):
    """Run ``fn(**kwargs)`` over ``examples`` sampled inputs; shrink failures."""

    def deco(fn: Callable):
        # NOTE: no functools.wraps — pytest would introspect __wrapped__ and
        # treat the strategy parameters as fixtures.
        def wrapper(*args):
            rng = np.random.default_rng(seed)
            for i in range(examples):
                kwargs = {k: s.sample(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **kwargs)
                except AssertionError:
                    kwargs = _shrink(fn, args, kwargs, strategies)
                    short = {k: np.asarray(v).reshape(-1)[:8] for k, v in kwargs.items()}
                    raise AssertionError(
                        f"property failed on example {i}; minimal-ish input: {short}"
                    ) from None
        wrapper.__name__ = getattr(fn, "__name__", "property")
        wrapper.__doc__ = fn.__doc__
        return wrapper

    return deco


def _shrink(fn, args, kwargs, strategies, rounds: int = 8):
    cur = dict(kwargs)
    for _ in range(rounds):
        progressed = False
        for k, strat in strategies.items():
            for cand in itertools.islice(strat.shrink(cur[k]), 4):
                trial = dict(cur)
                trial[k] = cand
                try:
                    fn(*args, **trial)
                except AssertionError:
                    cur = trial
                    progressed = True
                    break
        if not progressed:
            break
    return cur

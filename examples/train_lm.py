"""End-to-end driver: train a ~100M-param LM for a few hundred steps, then
deploy it with posit-compressed weights and measure the quality cost.

Pipeline (all on whatever devices exist — CPU here, a pod in production):

  synthetic data stream -> jit train step (remat, donated state, AdamW with
  posit8 moments) -> async checkpoints -> post-training quantization
  (normalized posit / PoFx) -> perplexity comparison fp32 vs pofx8 vs fxp8.

    PYTHONPATH=src python examples/train_lm.py --steps 300
(defaults to --steps 120 --small for a quick CPU run)
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, RunConfig, smoke
from repro.core.policy import QuantPolicy, storage_report
from repro.data import DataConfig, synthetic_batch
from repro.launch.train import make_train_state, make_train_step
from repro.nn.models import apply_policy, build_model, ce_loss
from repro.runtime import CheckpointManager, StepTimeMonitor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--small", action="store_true", default=True)
    ap.add_argument("--big", dest="small", action="store_false",
                    help="~100M params (slower on CPU)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = smoke(ARCHS["yi-9b"])
    if not args.small:
        # ~100M params: 12L x d512 x ff2048, 8 heads, 32k vocab
        base = dataclasses.replace(base, n_layers=12, d_model=512,
                                   n_heads=8, n_kv_heads=4, d_head=64,
                                   d_ff=2048, vocab_size=32000)
    cfg = base
    rcfg = RunConfig(learning_rate=1e-3, total_steps=args.steps,
                     warmup_steps=max(args.steps // 10, 1),
                     remat="block", opt_state_quant="posit8")
    model = build_model(cfg, rcfg)
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree.leaves(model.abstract_params()))
    print(f"model: {cfg.n_layers}L d={cfg.d_model} vocab={cfg.vocab_size} "
          f"-> {n_params/1e6:.1f}M params")

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch)
    state = make_train_state(model, jax.random.PRNGKey(0))
    manager = CheckpointManager(args.ckpt_dir, keep=2)
    start = 0
    if manager.latest_step() is not None:
        state = manager.restore()
        start = manager.latest_step() + 1
        print(f"resumed from step {start - 1}")
    step_fn = jax.jit(make_train_step(model), donate_argnums=(0,))
    mon = StepTimeMonitor()
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in synthetic_batch(dc, step).items()}
        mon.start()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        mon.stop()
        if step % 20 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(metrics['loss']):.4f} "
                  f"lr {float(metrics['lr']):.2e}")
        if step % 50 == 49:
            manager.save(step, state)
    manager.save(args.steps - 1, state)
    manager.wait()
    print(f"trained in {time.time()-t0:.1f}s | {mon.report()}")

    # ---- deployment: post-training posit quantization ----------------------
    params = state["params"]
    eval_batches = [synthetic_batch(dc, 10_000 + i) for i in range(4)]

    def ppl(p):
        tot = 0.0
        for b in eval_batches:
            logits = model.forward(p, jnp.asarray(b["tokens"]))
            tot += float(ce_loss(logits, jnp.asarray(b["labels"])))
        return float(np.exp(tot / len(eval_batches)))

    base_ppl = ppl(params)
    print(f"\n{'policy':<28} {'perplexity':>11} {'vs fp32':>9}")
    print(f"{'fp32':<28} {base_ppl:11.3f} {'-':>9}")
    for pol_s in ["pofx8es2", "pofx6es2", "fxp8f7",
                  "attn/*=pofx8es2,mlp/*=fxp8f7,*=bf16"]:
        qp = apply_policy(params, pol_s)
        p = ppl(qp)
        print(f"{pol_s:<28} {p:11.3f} {p/base_ppl:8.3f}x")

    # quantized checkpoint round-trip: codes + policy metadata at rest
    policy = QuantPolicy.from_string("paper-table6")
    qp = apply_policy(params, policy)
    qm = CheckpointManager(args.ckpt_dir + "_quant", keep=1, async_save=False)
    qm.save(args.steps - 1, {"params": qp}, policy=policy)
    print(f"\nsaved quantized checkpoint "
          f"(policy={qm.read_manifest()['quant_policy']}):")
    print(storage_report(qm.restore()["params"], policy))


if __name__ == "__main__":
    main()

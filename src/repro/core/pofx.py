"""PoFx — the ExPAN(N)D Posit -> fixed-point converter (Algorithm 1).

Bit-faithful, vectorized port of the paper's five-stage converter:

  A1  sign extraction, implicit magnitude bit at position F
  A2  conditional two's complement of the body
  A3  modified leading-zero detector (invert-if-leading-zero + AND chain)
  B1  regime evaluation: V = popcount(LZD), k = -V or V-1
  B2  exponent/fraction extraction (the "silhouette" barrel extractor is
      realized as a left-align + fixed split — bit-identical result)
  C   SHIFT = 2^ES * k + e
  D   barrel shift of the magnitude (right shifts TRUNCATE, exactly like the
      RTL shifter; optional round-to-nearest provided as a beyond-paper knob)
  E   sign-magnitude -> two's complement

Output is FxP(M, F): an M-bit two's-complement integer whose value is
``code / 2^F``.  Saturation to +/-(2^(M-1)-1) raises the overflow semantics
the paper assigns to the OF flag (returned alongside).

The *normalized* variant (paper §4.1.2) takes (N-1)-bit normalized codes,
replicates the leading bit (Stage A), and — because every magnitude is < 1 —
only ever shifts right.  ``-1`` is not extractable in sign-magnitude FxP(M,
F=M-1); like the paper we flag OF and saturate to -(1 - 2^-F).

``pofx_lut`` builds the full decode table with the bit-level algorithm; the
Pallas kernels and jit paths may use either (tested equal).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .normalized_posit import norm_expand
from .posit import NAR, _decode_fields

__all__ = [
    "pofx_convert",
    "pofx_convert_np",
    "pofx_normalized",
    "pofx_normalized_np",
    "pofx_lut",
    "pofx_norm_lut",
]


def _shift_trunc(mag, shift, xp, left_clamp: int, wide):
    """Barrel shift with truncating right-shift (Stage D semantics).

    Left shifts are clamped to ``left_clamp``: the magnitude field holds
    <= N bits, so the product never wraps the wide integer type, while any
    clamped shift still exceeds every supported M-bit output range and
    saturates downstream — clamping preserves the OF semantics exactly.
    """
    left = xp.clip(shift, 0, left_clamp)
    right = xp.clip(-shift, 0, 62 if wide == xp.int64 else 31)
    w = mag.astype(wide)
    return xp.where(shift >= 0, w << left, w >> right)


def _pofx_impl(codes, N: int, ES: int, M: int, F: int, xp, rounding: str):
    c = xp.asarray(codes).astype(xp.int32) & ((1 << N) - 1)
    # jnp runs int32 (x64 disabled by default); numpy golden uses int64.
    if xp is np:
        wide, left_clamp = np.int64, 45
    else:
        wide, left_clamp = xp.int32, 31 - N
        if M > 31:
            raise ValueError("jnp PoFx supports M <= 31 (int32 datapath)")
    # Stages A1-A3 + B1-B2 share the decode datapath (sign, regime k,
    # exponent e, fraction left-aligned in an (N-1)-bit window).
    s, k, e, frac = _decode_fields(c, N, ES, xp)
    # A1: implicit leading one. MAG_ext is a fixed-point magnitude with
    # (N-1) fraction bits: 1.f * 2^(N-1).
    mag_ext = (1 << (N - 1)) | frac
    # C: SHIFT = 2^ES * k + e, retargeted to F output fraction bits.
    shift = (k << ES) + e + (F - (N - 1))
    if rounding == "nearest":
        # Beyond-paper knob: add half-ulp before a truncating right shift.
        right = xp.where(shift < 0, -shift, 0)
        rc = xp.clip(right, 0, 62 if wide == np.int64 else 31)
        half = xp.where(right > 0, (1 << xp.clip(rc - 1, 0, 30)).astype(wide), 0)
        mag = _shift_trunc(mag_ext, shift, xp, left_clamp, wide)
        mag_r = (mag_ext.astype(wide) + half) >> rc
        mag = xp.where(shift < 0, mag_r, mag)
    else:
        mag = _shift_trunc(mag_ext, shift, xp, left_clamp, wide)
    # D: saturate to the M-bit sign-magnitude range; OF per paper.
    max_mag = (1 << (M - 1)) - 1
    of = mag > max_mag
    mag = xp.clip(mag, 0, max_mag).astype(xp.int32)
    # E: sign-magnitude -> two's complement.
    out = xp.where(s == 1, -mag, mag).astype(xp.int32)
    out = xp.where(c == 0, 0, out)
    nar = c == NAR(N)
    out = xp.where(nar, 0, out)
    return out, (of & ~(c == 0) & ~nar)


def pofx_convert_np(codes, N: int, ES: int, M: int, F: int, rounding: str = "trunc"):
    """Golden numpy Algorithm-1 conversion. Returns (fxp_codes, of_flags)."""
    return _pofx_impl(np.asarray(codes), N, ES, M, F, np, rounding)


def pofx_convert(codes, N: int, ES: int, M: int, F: int, rounding: str = "trunc"):
    """jnp Algorithm-1 conversion (jit friendly). Returns (fxp_codes, of)."""
    return _pofx_impl(jnp.asarray(codes), N, ES, M, F, jnp, rounding)


def _norm_impl(codes_nm1, N: int, ES: int, M: int, xp, rounding: str):
    # Stage A of the normalized variant: replicate the stored leading bit.
    full = norm_expand(codes_nm1, N)
    # F = M-1: all output bits but the sign carry fraction (paper §4.1.2).
    out, of = _pofx_impl(full, N, ES, M, M - 1, xp, rounding)
    return out, of


def pofx_normalized_np(codes_nm1, N: int, ES: int, M: int, rounding: str = "trunc"):
    return _norm_impl(np.asarray(codes_nm1), N, ES, M, np, rounding)


def pofx_normalized(codes_nm1, N: int, ES: int, M: int, rounding: str = "trunc"):
    return _norm_impl(jnp.asarray(codes_nm1), N, ES, M, jnp, rounding)


@functools.lru_cache(maxsize=64)
def pofx_lut(N: int, ES: int, M: int, F: int, rounding: str = "trunc") -> np.ndarray:
    """Full 2^N-entry Posit->FxP decode table (bit-level algorithm)."""
    codes = np.arange(1 << N, dtype=np.int32)
    out, _ = pofx_convert_np(codes, N, ES, M, F, rounding)
    return out.astype(np.int32)


@functools.lru_cache(maxsize=64)
def pofx_norm_lut(N: int, ES: int, M: int, rounding: str = "trunc") -> np.ndarray:
    """2^(N-1)-entry normalized-posit -> FxP(M, M-1) decode table."""
    codes = np.arange(1 << (N - 1), dtype=np.int32)
    out, _ = pofx_normalized_np(codes, N, ES, M, rounding)
    return out.astype(np.int32)

"""Public jit'd entry points for the kernels, with automatic dispatch.

``quant_matmul`` is what the model layers call: given activations and a
QuantizedTensor weight it picks the right datapath —

  pofx   + use_kernel   -> fused Pallas decode+matmul (Move & Store)
  pofx   + no kernel    -> LUT dequantize + XLA matmul (Move; decode at load)
  fxp    + int8 acts    -> int8 MXU MAC (fxp_matmul)
  others                -> dequantize + XLA matmul

On this CPU container kernels run in interpret mode; on TPU they compile to
Mosaic. ``use_kernel="auto"`` keeps kernels out of huge jit graphs (the
dry-run lowers the XLA path; kernels are validated separately).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantizers import QuantizedTensor, dequantize, fxp_view
from .fxp_matmul import fxp_matmul
from .pofx_decode import pofx_decode
from .pofx_matmul import pofx_matmul

__all__ = ["quant_matmul", "pofx_decode", "pofx_matmul", "fxp_matmul"]


def quant_matmul(x: jax.Array, w: QuantizedTensor, *,
                 use_kernel: bool = False,
                 out_dtype=None) -> jax.Array:
    """x @ dequant(w); x: (..., k), w codes: (k, n)."""
    out_dtype = out_dtype or x.dtype
    spec = w.spec
    lead = x.shape[:-1]
    k = x.shape[-1]
    x2 = x.reshape(-1, k)
    if spec.kind == "pofx" and use_kernel:
        scale = jnp.broadcast_to(w.scale, (1, w.codes.shape[-1])).reshape(-1)
        y = pofx_matmul(x2, w.codes, scale, spec.N, spec.ES, spec.M)
        return y.reshape(*lead, -1).astype(out_dtype)
    if spec.kind == "fxp" and use_kernel:
        codes, rescale = fxp_view(w)
        # int8 activations: per-tensor symmetric quantization of x.
        xmax = jnp.maximum(jnp.max(jnp.abs(x2)), 1e-6)
        xq = jnp.clip(jnp.round(x2 / xmax * 127.0), -127, 127).astype(jnp.int8)
        acc = fxp_matmul(xq, codes)
        y = acc.astype(jnp.float32) * (xmax / 127.0) * jnp.reshape(rescale, (1, -1))
        return y.reshape(*lead, -1).astype(out_dtype)
    wv = dequantize(w, jnp.bfloat16 if out_dtype == jnp.bfloat16 else jnp.float32)
    y = jnp.dot(x2.astype(wv.dtype), wv, preferred_element_type=jnp.float32)
    return y.reshape(*lead, -1).astype(out_dtype)

"""Posit codec tests: Table 2 golden values, exhaustive bit-level checks,
jnp==numpy exactness, and property tests (encode/decode invariants)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    NAR,
    norm_compress,
    norm_decode_np,
    norm_encode_np,
    norm_expand,
    norm_max,
    pack_bits,
    posit_decode,
    posit_decode_np,
    posit_encode,
    posit_encode_np,
    posit_max,
    posit_min_pos,
    posit_value_table,
    unpack_bits,
)
from proptest import Floats, given

ALL_CONFIGS = [(N, ES) for N in range(4, 11) for ES in range(0, 4)] + [(16, 2), (16, 3), (12, 1)]


def test_table2_paper_values():
    """Exact reproduction of paper Table 2: Posit(4,0)."""
    vals = posit_decode_np(np.arange(16), 4, 0)
    expect = [0, 0.25, 0.5, 0.75, 1, 1.5, 2, 4,
              np.nan, -4, -2, -1.5, -1, -0.75, -0.5, -0.25]
    for c, (v, e) in enumerate(zip(vals, expect)):
        if np.isnan(e):
            assert np.isnan(v), c
        else:
            assert v == e, (c, v, e)


def test_table2_normalized_mapping():
    """Paper Table 2 highlighted rows: posit <-> ExPAN(N)D code mapping."""
    posit_codes = [0b0000, 0b0001, 0b0010, 0b0011, 0b1100, 0b1101, 0b1110, 0b1111]
    expannd = [0b000, 0b001, 0b010, 0b011, 0b100, 0b101, 0b110, 0b111]
    got = norm_compress(np.array(posit_codes), 4)
    assert list(got) == expannd
    assert list(norm_expand(np.array(expannd), 4)) == posit_codes


@pytest.mark.parametrize("N,ES", ALL_CONFIGS)
def test_decode_monotonic_and_symmetric(N, ES):
    codes = np.arange(1 << N)
    vals = posit_decode_np(codes, N, ES)
    # signed-code ordering == value ordering (posit core property)
    signed = np.where(codes >= (1 << (N - 1)), codes - (1 << N), codes)
    order = np.argsort(signed)
    v = vals[order]
    v = v[~np.isnan(v)]
    assert np.all(np.diff(v) > 0)
    # negation symmetry: decode(-c) == -decode(c)
    pos = codes[1: 1 << (N - 1)]
    neg = (-pos) & ((1 << N) - 1)
    assert np.array_equal(posit_decode_np(neg, N, ES), -vals[pos])


@pytest.mark.parametrize("N,ES", ALL_CONFIGS)
def test_jnp_decode_exact(N, ES):
    c = np.arange(1 << N)
    a = posit_decode_np(c, N, ES)
    b = np.asarray(posit_decode(jnp.asarray(c), N, ES), dtype=np.float64)
    m = ~np.isnan(a)
    assert np.array_equal(a[m], b[m])
    assert np.isnan(b[~m]).all()


@pytest.mark.parametrize("N,ES", [(8, 2), (7, 1), (5, 0), (16, 2), (6, 3)])
def test_encode_roundtrip_identity(N, ES):
    """Every representable posit value encodes back to its own code."""
    c = np.arange(1 << N)
    v = posit_decode_np(c, N, ES)
    m = ~np.isnan(v)
    assert np.array_equal(posit_encode_np(v[m], N, ES), c[m])
    # NaN -> NaR
    assert posit_encode_np(np.array([np.nan]), N, ES)[0] == NAR(N)


@pytest.mark.parametrize("N,ES", [(8, 2), (6, 1)])
def test_encode_jnp_matches_np(N, ES):
    rng = np.random.default_rng(1)
    x = (rng.standard_normal(4096) * np.exp2(rng.integers(-20, 10, 4096))).astype(np.float32)
    a = posit_encode_np(x.astype(np.float64), N, ES)
    b = np.asarray(posit_encode(jnp.asarray(x), N, ES))
    assert np.array_equal(a, b)


@given(seed=7, examples=30, x=Floats(lo=-1e6, hi=1e6, shape=(256,)))
def test_encode_is_nearest(x):
    """Property: |decode(encode(x)) - x| <= distance to any lattice value."""
    N, ES = 8, 2
    table = posit_value_table(N, ES)
    full = np.concatenate([-table[::-1], table])
    code = posit_encode_np(x, N, ES)
    back = posit_decode_np(code, N, ES)
    err = np.abs(back - x)
    # nearest lattice distance (saturation: clamp to [min, max])
    xc = np.clip(x, full[0], full[-1])
    best = np.min(np.abs(full[None, :] - xc[:, None]), axis=1)
    pad = np.abs(x - xc)  # saturation penalty is unavoidable
    assert np.all(err <= best + pad + 1e-12)


@given(seed=3, examples=30, x=Floats(lo=-8.0, hi=8.0, shape=(128,)))
def test_normalized_encode_saturates(x):
    """Property: normalized codes decode into [-1, norm_max]."""
    N, ES = 8, 1
    code = norm_encode_np(x, N, ES)
    assert np.all(code < (1 << (N - 1)))
    v = norm_decode_np(code, N, ES)
    assert np.all(v >= -1.0) and np.all(v <= norm_max(N, ES)) and norm_max(N, ES) < 1.0
    # in-range values quantize with bounded error (<= one lattice gap)
    inside = (np.abs(x) <= 1.0)
    assert np.all(np.abs(v[inside] - x[inside]) <= 0.26)  # coarsest gap near +/-1 is < 2^-2


@pytest.mark.parametrize("N,ES", [(6, 0), (8, 2), (9, 3)])
def test_normalized_roundtrip_all_codes(N, ES):
    nm = np.arange(1 << (N - 1))
    v = norm_decode_np(nm, N, ES)
    assert np.array_equal(norm_encode_np(v, N, ES), nm)


@pytest.mark.parametrize("k", [3, 5, 7, 8, 11, 15])
def test_bit_packing_roundtrip(k):
    rng = np.random.default_rng(k)
    codes = rng.integers(0, 1 << k, size=999)
    packed = pack_bits(codes, k)
    assert packed.size == int(np.ceil(999 * k / 8))
    assert np.array_equal(unpack_bits(packed, k, 999), codes)


def test_minmax_helpers():
    assert posit_max(8, 2) == posit_decode_np(np.array([127]), 8, 2)[0]
    assert posit_min_pos(8, 2) == posit_decode_np(np.array([1]), 8, 2)[0]

"""The paper's Fig. 8 behavioral-analysis framework, end to end.

Takes a (small, freshly trained) LM, runs the three-level quantization
error pipeline over the full (FxP | Posit | PoFx) config grid, prunes
infeasible configs level by level, and prints the survivors with their
storage cost — the ExPAN(N)D design-space exploration front-end.

    PYTHONPATH=src python examples/behavioral_analysis.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, RunConfig, smoke
from repro.core.analysis import default_spec_grid, spec_name, sweep_configs
from repro.core.policy import policy_from_pareto, storage_report
from repro.data import DataConfig, synthetic_batch
from repro.launch.train import make_train_state, make_train_step
from repro.nn.models import apply_policy, build_model, ce_loss, quantize_params


def main():
    cfg = smoke(ARCHS["yi-9b"])
    rcfg = RunConfig(learning_rate=1e-3, total_steps=60, warmup_steps=6,
                     remat="none")
    model = build_model(cfg, rcfg)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=8)
    state = make_train_state(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model), donate_argnums=(0,))
    for step in range(60):
        batch = {k: jnp.asarray(v) for k, v in synthetic_batch(dc, step).items()}
        state, metrics = step_fn(state, batch)
    params = state["params"]
    print(f"trained 60 steps; loss={float(metrics['loss']):.3f}")

    # level a inputs: the attention/MLP weight matrices of layer 0
    blocks = params["blocks"]
    weights = {
        "wq": jnp.asarray(blocks["attn"]["wq"][0].reshape(cfg.d_model, -1)),
        "wo": jnp.asarray(blocks["attn"]["wo"][0]),
        "wg": jnp.asarray(blocks["mlp"]["wg"][0]),
        "unembed": jnp.asarray(params["unembed"]),
    }
    # level b: apply-fns per weight (the layer's matmul on a cached input)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    layer_apply = {k: ((lambda w, x: x @ w), x) for k in ("wq", "wg")}

    # level c: end-to-end eval loss with the whole net quantized
    eval_batch = synthetic_batch(dc, 9_999)

    def end_to_end(spec):
        qp = quantize_params(params, spec)
        logits = model.forward(qp, jnp.asarray(eval_batch["tokens"]))
        return -float(ce_loss(logits, jnp.asarray(eval_batch["labels"])))

    report = sweep_configs(
        weights, default_spec_grid(include_paths=True),
        layer_apply=layer_apply, end_to_end=end_to_end,
        prune_weight_err=0.25, prune_act_err=0.25)

    print(f"\npruned at level a (weight err): {report.pruned_at_a}")
    print(f"pruned at level b (activation err): {report.pruned_at_b}")
    print(f"survivors: {len(report.survivors)}")
    print("\n" + report.table())

    # recommend: best accuracy per storage budget
    best = {}
    for name, rec in report.per_config.items():
        if rec.get("pruned") or "metric" not in rec:
            continue
        b = round(rec["bits_per_weight"])
        if b not in best or rec["metric"] > best[b][1]:
            best[b] = (name, rec["metric"])
    print("\nbest config per stored-bit budget:")
    for b in sorted(best):
        print(f"  {b:2d} bits/weight -> {best[b][0]:<22} "
              f"eval_nll={-best[b][1]:.4f}")

    # format search -> QuantPolicy: per layer group, pick the cheapest
    # Pareto-front format meeting the error budget (Table 6 methodology).
    groups = {
        "attn/*": [weights["wq"], weights["wo"]],
        "mlp/*": [weights["wg"]],
        "*embed*": [weights["unembed"]],
    }
    policy = policy_from_pareto(groups, max_avg_rel=0.05, fallback="pofx8es2")
    print(f"\npareto-derived policy: {policy.to_string()}")
    qp = apply_policy(params, policy)
    print(storage_report(qp, policy))
    logits = model.forward(qp, jnp.asarray(eval_batch["tokens"]))
    nll = float(ce_loss(logits, jnp.asarray(eval_batch["labels"])))
    print(f"eval_nll under pareto policy: {nll:.4f}")


if __name__ == "__main__":
    main()

"""Table 2: exhaustive Posit(4,0) <-> normalized-posit mapping.

Reproduces the paper's table exactly: the 8 normalized patterns, their
values, and the dropped-leading-bit encoding; verifies the two leading bits
of every normalized pattern are identical and the 3-bit codes round-trip.
"""
from __future__ import annotations

import numpy as np

from repro.core.normalized_posit import norm_compress, norm_expand
from repro.core.posit import posit_decode_np

from .common import write_csv

# the paper's Table 2 value column for Posit(4,0), codes 0..15
PAPER_VALUES = [0.0, 0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 4.0,
                float("nan"), -4.0, -2.0, -1.5, -1.0, -0.75, -0.5, -0.25]


def run():
    N, ES = 4, 0
    codes = np.arange(16)
    vals = posit_decode_np(codes, N, ES)
    rows = []
    ok_values = True
    for c, v in zip(codes, vals):
        pv = PAPER_VALUES[c]
        match = (np.isnan(v) and np.isnan(pv)) or v == pv
        ok_values &= bool(match)
        bits = format(c, "04b")
        normalized = bits[0] == bits[1] and not (np.isnan(v)) and abs(v) <= 1 \
            and v != 1.0 and v != -1.0 or (v == -1.0)
        # paper keeps codes with |v| <= 1 except +1 (not representable after
        # dropping the bit on the positive side; -1 is kept)
        in_table = bits[0] == bits[1]
        row = {"posit_bits": bits, "value": v, "paper_value": pv,
               "normalized": in_table}
        if in_table:
            nm = int(norm_compress(np.asarray([c]), N)[0])
            row["expand_bits"] = format(nm, "03b")
            row["roundtrip_ok"] = int(norm_expand(np.asarray([nm]), N)[0]) == c
        rows.append(row)
    write_csv("table2_normposit", rows)
    norm_rows = [r for r in rows if r["normalized"]]
    all_rt = all(r.get("roundtrip_ok") for r in norm_rows)
    return rows, {
        "values_match_paper": ok_values,
        "n_normalized_patterns": len(norm_rows),   # paper: 8
        "roundtrip_ok": all_rt,
        "leading_bits_identical": all(
            r["posit_bits"][0] == r["posit_bits"][1] for r in norm_rows),
    }

"""Optimizer: schedule shape, clipping, decay, posit8-moment parity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (OptConfig, apply_updates, global_norm,
                         init_opt_state, lr_schedule)


def test_lr_schedule_warmup_and_cosine():
    ocfg = OptConfig(learning_rate=1.0, warmup_steps=10, total_steps=110,
                     min_lr_frac=0.1)
    lrs = [float(lr_schedule(jnp.asarray(s), ocfg)) for s in range(0, 120, 5)]
    assert lrs[1] < lrs[2] <= 1.0                 # warming up
    assert abs(max(lrs) - 1.0) < 1e-5
    assert abs(lrs[-1] - 0.1) < 1e-5              # floor at min_lr_frac


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros((4, 4))}
    ocfg = OptConfig(learning_rate=1.0, warmup_steps=0, total_steps=10,
                     grad_clip=1.0, weight_decay=0.0)
    opt = init_opt_state(params)
    huge = {"w": jnp.full((4, 4), 1e6)}
    new_p, opt, m = apply_updates(params, huge, opt, ocfg)
    assert float(m["grad_norm"]) > 1e6
    assert float(jnp.max(jnp.abs(new_p["w"]))) < 10.0


def test_weight_decay_only_on_matrices():
    params = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    ocfg = OptConfig(learning_rate=0.1, warmup_steps=0, total_steps=10,
                     weight_decay=0.5, grad_clip=0.0)
    opt = init_opt_state(params)
    zero = jax.tree.map(jnp.zeros_like, params)
    new_p, _, _ = apply_updates(params, zero, opt, ocfg)
    assert float(new_p["w"][0, 0]) < 1.0          # decayed
    assert float(new_p["b"][0]) == 1.0            # not decayed


def test_posit8_moments_track_fp32_closely():
    """Same rosenbrock-ish descent with fp32 vs posit8 moments."""
    def grads(p):
        return {"w": 2 * p["w"] + 0.1 * jnp.sin(p["w"])}

    hist = {}
    for quant in ("none", "posit8"):
        params = {"w": jnp.full((8, 8), 1.5)}
        ocfg = OptConfig(learning_rate=0.05, warmup_steps=0, total_steps=100,
                         weight_decay=0.0, quant=quant)
        opt = init_opt_state(params, quant)
        for _ in range(100):
            params, opt, _ = apply_updates(params, grads(params), opt, ocfg)
        hist[quant] = float(jnp.abs(params["w"]).max())
    assert hist["posit8"] < 0.05
    assert abs(hist["posit8"] - hist["none"]) < 0.02


def test_posit8_moment_storage_is_uint8():
    from repro.core.quantizers import QuantizedTensor
    params = {"w": jnp.ones((16, 16))}
    opt = init_opt_state(params, "posit8")
    m = opt["m"]["w"]
    assert isinstance(m, QuantizedTensor)
    assert m.codes.dtype == jnp.uint8
    assert m.codes.shape == (16, 16)


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": 2 * jnp.ones((4,))}
    assert abs(float(global_norm(t)) - np.sqrt(3 + 16)) < 1e-6

"""Mixture-of-Experts: top-k router + sort-based capacity dispatch.

Fixed-shape, MXU-friendly dispatch (MaxText/GShard "dropping" style, but via
sort instead of dense one-hot einsums so dispatch cost is O(T k log T), not
O(T·E·C·d)):

  1. router logits (f32, never quantized — see DESIGN.md §5) -> top-k ids
  2. stable-sort the T*k (expert, token) assignments by expert
  3. position-in-expert via searchsorted; tokens beyond capacity C drop
  4. scatter to (E, C, d) -> per-expert batched matmuls (MXU) -> gather back

Expert weights are sharded over the model axis (EP); the (E, C, d) dispatch
resharding is where GSPMD emits the all-to-all the paper's communication
column talks about. Expert FFN weights are the paper's best posit case:
n_experts copies of cold parameters (quantizable via QuantSpec like any
other matmul weight).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import (activation, dense_init, is_gated, matmul_param,
                     mlp_init, mlp_logical, param_value)


def moe_init(key, cfg, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 5)
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    gated = is_gated(cfg.act)
    p = {"router": dense_init(ks[0], d, E, dtype=jnp.float32)}
    def ew(k, i, o):  # stacked expert weights (E, in, out)
        return (jax.random.normal(k, (E, i, o)) * i ** -0.5).astype(dtype)
    if gated:
        p.update(wg=ew(ks[1], d, ff), wu=ew(ks[2], d, ff), wo=ew(ks[3], ff, d))
    else:
        p.update(wi=ew(ks[1], d, ff), wo=ew(ks[3], ff, d))
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, ff * cfg.n_shared_experts, cfg.act, dtype)
    return p


def moe_logical(cfg) -> dict:
    gated = is_gated(cfg.act)
    p = {"router": ("p_unsharded", "p_unsharded")}
    if gated:
        p.update(wg=("experts", "p_embed", None), wu=("experts", "p_embed", None),
                 wo=("experts", None, "p_embed"))
    else:
        p.update(wi=("experts", "p_embed", None), wo=("experts", None, "p_embed"))
    if cfg.n_shared_experts:
        p["shared"] = mlp_logical(cfg.act)
    return p


def moe_forward(p: dict, x: jax.Array, cfg, ctx, use_kernel: bool = False) -> jax.Array:
    """x: (B, S, d) -> (B, S, d).

    Decode (S == 1) uses drop-free capacity C = T*k — a handful of tokens;
    train/prefill uses the GShard capacity factor (dropping is part of the
    algorithm there, and keeps shapes static for the MXU).
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)
    # 1. route (f32 for numerical routing stability)
    logits = jnp.dot(xt.astype(jnp.float32), param_value(p["router"], jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, topk_idx = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    # 2. sort assignments by expert
    flat_expert = topk_idx.reshape(-1)                      # (T*k,)
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    # 3. position within expert, capacity mask
    C = T * k if S == 1 else int(max(1, round(T * k * cfg.capacity_factor / E)))
    starts = jnp.searchsorted(sorted_expert, jnp.arange(E))
    pos = jnp.arange(T * k) - starts[sorted_expert]
    keep = pos < C
    slot = jnp.where(keep, sorted_expert * C + pos, E * C)  # drop row at E*C
    token_of = order // k
    # 4. scatter -> (E, C, d)
    disp = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(
        xt[token_of] * keep[:, None].astype(x.dtype))
    disp = disp[:-1].reshape(E, C, d)
    disp = ctx.constrain(disp, "experts", "expert_cap", None)
    # 5. expert FFN (batched over E; EP-sharded). Inside a manual-TP
    # shard_map the weight leaves carry only E/tp local experts: routing
    # and dispatch ran replicated over the GLOBAL expert ids above, so each
    # device slices its expert rows out of the dispatch, computes its local
    # FFNs, and scatters the results back into the global (E*C, d) layout —
    # rows of non-local experts stay zero and the combine's psum below sums
    # the disjoint per-device partials into the full mixture.
    E_loc = p["wo"].shape[0]
    if E_loc != E:
        e0 = jax.lax.axis_index(ctx.tp_axis) * E_loc
        disp_e = jax.lax.dynamic_slice_in_dim(disp, e0, E_loc, axis=0)
    else:
        disp_e = disp
    fn = activation(cfg.act)
    if is_gated(cfg.act):
        g = jnp.einsum("ecd,edf->ecf", disp_e, param_value(p["wg"], x.dtype))
        u = jnp.einsum("ecd,edf->ecf", disp_e, param_value(p["wu"], x.dtype))
        h = fn(g) * u
    else:
        h = fn(jnp.einsum("ecd,edf->ecf", disp_e, param_value(p["wi"], x.dtype)))
    h = ctx.constrain(h, "experts", "expert_cap", None)
    out_e = jnp.einsum("ecf,efd->ecd", h, param_value(p["wo"], x.dtype))
    out_e = ctx.constrain(out_e, "experts", "expert_cap", None)
    if E_loc != E:
        out_e = jax.lax.dynamic_update_slice_in_dim(
            jnp.zeros((E, C, d), out_e.dtype), out_e, e0, axis=0)
    # 6. gather back + weighted combine
    out_flat = out_e.reshape(E * C, d)
    gathered = jnp.where(keep[:, None], out_flat[jnp.clip(slot, 0, E * C - 1)], 0.0)
    gates_sorted = gate_vals.reshape(-1)[order]
    contrib = gathered * gates_sorted[:, None].astype(x.dtype)
    y = jnp.zeros((T, d), x.dtype).at[token_of].add(contrib)
    if E_loc != E:
        y = ctx.psum(y)     # the MoE block's one expert-combine collective
    if cfg.n_shared_experts:
        from .layers import mlp_forward
        # mlp_forward psums its own (tp-sharded) down-proj, so the shared
        # contribution adds in AFTER the expert psum — full on every device.
        y = y + mlp_forward(p["shared"], xt[None], cfg.act, ctx,
                            use_kernel=use_kernel)[0]
    return y.reshape(B, S, d)


def router_aux_loss(p, x, cfg) -> jax.Array:
    """Load-balancing auxiliary loss (Switch-style f*P)."""
    T = x.shape[0] * x.shape[1]
    logits = jnp.dot(x.reshape(T, -1).astype(jnp.float32), param_value(p["router"], jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    _, topk_idx = jax.lax.top_k(probs, cfg.top_k)
    frac = jnp.mean(jax.nn.one_hot(topk_idx, cfg.n_experts, dtype=jnp.float32), axis=(0, 1))
    return cfg.n_experts * jnp.sum(frac * probs.mean(0))

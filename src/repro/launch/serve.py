"""Serving driver: prefill a batch of prompts, decode with donated cache.

Demonstrates the paper's deployment story end to end on real (CPU-sized)
shapes: weights post-training-quantized per a QuantPolicy — one format
(``--quant pofx8es2``) or mixed per-layer formats
(``--quant "attn/*=pofx8es2,mlp/*=fxp8f7,*=bf16"``) — the KV cache donated
and updated in place, greedy decode. Prints tokens/s and a per-rule
parameter-storage breakdown (the paper's Table 6 storage rows, measured on
the actual pytree).

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
        --quant pofx8 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, RunConfig, smoke as smoke_cfg
from repro.core.policy import QuantPolicy, add_policy_arg, storage_report
from repro.nn.models import apply_policy, build_model

# Back-compat name; the policy-aware report lives in repro.core.policy.
param_storage_report = storage_report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="yi-9b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    add_policy_arg(ap, default="pofx8")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = smoke_cfg(cfg)
    rcfg = RunConfig(remat="none")
    model = build_model(cfg, rcfg)
    params = model.init(jax.random.PRNGKey(0))
    policy = QuantPolicy.from_string(args.quant)
    params = apply_policy(params, policy)
    print(f"[{args.arch} quant={policy.to_string()}]")
    print(storage_report(params, policy))

    B, P = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size)
    frames = None
    if cfg.family == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(2), (B, P, cfg.d_model),
                                   jnp.float32)
    max_len = P + args.gen + 1
    cache = model.init_cache(B, max_len, enc_len=P)

    t0 = time.perf_counter()
    # frames is a real jit argument (not a closed-over constant): a new
    # encoder batch must not silently reuse the baked-in prefill trace.
    cache, logits = jax.jit(
        lambda p, c, t, f: model.prefill(p, t, cache=c, frames=f),
        donate_argnums=(1,))(params, cache, prompts, frames)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    tok = jnp.argmax(logits, axis=-1)[:, None]
    outs = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen):
        cache, logits = decode(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1)[:, None]
        outs.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.asarray(jnp.concatenate(outs, axis=1))
    assert not np.any(np.isnan(np.asarray(logits))), "NaN logits"
    print(f"prefill: {B}x{P} tokens in {t_prefill:.3f}s "
          f"({B*P/t_prefill:.0f} tok/s)")
    print(f"decode:  {args.gen} steps x {B} seqs in {t_decode:.3f}s "
          f"({args.gen*B/t_decode:.1f} tok/s)")
    print("sample:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()

"""Compiled-HLO analysis: collective bytes-on-wire + roofline terms.

``parse_collectives`` scans post-SPMD HLO text for every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, reads the
result shapes and replica-group sizes, and converts each to **bytes on the
wire per device** with ring-algorithm accounting:

    all-gather          out_bytes * (g-1)/g
    all-reduce          2 * bytes * (g-1)/g     (reduce-scatter + all-gather)
    reduce-scatter      out_bytes * (g-1)        (input = out * g)
    all-to-all          tuple_bytes * (g-1)/g
    collective-permute  bytes                    (one send/recv)

Collectives inside scan bodies appear once in the HLO but execute
trip-count times. XLA's cost analysis accounts for this in FLOPs; for the
wire bytes we multiply by the enclosing scan lengths, which the caller
supplies as ``trip_counts`` = [len(outer scan), len(inner scan), ...] and we
locate by counting ``/while/body`` frames in the op metadata. This is the
pinned methodology for EXPERIMENTS.md §Roofline.

Roofline constants (TPU v5e class, per chip):
    197 TFLOP/s bf16 | 819 GB/s HBM | ~50 GB/s/link ICI
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

__all__ = ["parse_collectives", "CollectiveStats", "roofline_terms",
           "PEAK_FLOPS_BF16", "HBM_BW", "ICI_BW"]

PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<result>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_BRACKET_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]*)\}")
_WHILE_RE = re.compile(r"/while/body")


def _shape_bytes(result: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(result):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    ops: List[Dict]                      # per-op records
    wire_bytes_per_device: float         # trip-count adjusted
    by_kind: Dict[str, float]

    def summary(self) -> str:
        rows = [f"  {k:20s} {v/1e6:12.2f} MB/device"
                for k, v in sorted(self.by_kind.items())]
        rows.append(f"  {'TOTAL':20s} {self.wire_bytes_per_device/1e6:12.2f}"
                    " MB/device")
        return "\n".join(rows)


def parse_collectives(hlo_text: str,
                      trip_counts: Optional[List[int]] = None
                      ) -> CollectiveStats:
    trip_counts = trip_counts or []
    ops = []
    by_kind: Dict[str, float] = {}
    total = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        kind = m.group("kind")
        bytes_ = _shape_bytes(m.group("result"))
        gb = _GROUPS_BRACKET_RE.search(line)
        if gb:
            g = int(gb.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            g = len(gl.group(1).split(",")) if gl else 2
        if g <= 1:
            continue
        if kind == "all-gather":
            wire = bytes_ * (g - 1) / g
        elif kind == "all-reduce":
            wire = 2 * bytes_ * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = bytes_ * (g - 1)
        elif kind == "all-to-all":
            wire = bytes_ * (g - 1) / g
        else:  # collective-permute
            wire = bytes_
        depth = len(_WHILE_RE.findall(line))
        mult = 1
        for i in range(min(depth, len(trip_counts))):
            mult *= trip_counts[i]
        if depth > len(trip_counts) and trip_counts:
            mult *= trip_counts[-1]
        wire *= mult
        ops.append({"kind": kind, "bytes": bytes_, "group": g,
                    "depth": depth, "mult": mult, "wire": wire})
        by_kind[kind] = by_kind.get(kind, 0.0) + wire
        total += wire
    return CollectiveStats(ops, total, by_kind)


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   wire_bytes_per_device: float,
                   model_flops_global: float, n_devices: int) -> Dict[str, float]:
    """The three §Roofline terms, in seconds, plus derived ratios."""
    t_compute = flops_per_device / PEAK_FLOPS_BF16
    t_memory = bytes_per_device / HBM_BW
    t_collective = wire_bytes_per_device / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_collective}
    bound = max(terms, key=terms.get)
    t_bound = terms[bound]
    model_t = model_flops_global / (n_devices * PEAK_FLOPS_BF16)
    return {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_collective,
        "bound": bound,
        "step_lower_bound_s": t_bound,
        # fraction of peak compute achievable at the roofline bound
        "mfu_bound": (model_t / t_bound) if t_bound > 0 else float("nan"),
        "useful_flops_ratio": (model_flops_global
                               / (flops_per_device * n_devices)
                               if flops_per_device else float("nan")),
    }

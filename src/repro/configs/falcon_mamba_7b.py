"""falcon-mamba-7b [ssm]: 64L d_model=4096 attn-free vocab=65024,
ssm_state=16 — mamba1 selective-scan arch [arXiv:2410.05355]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab_size=65024,
    ssm_state=16, d_inner=8192, conv_width=4, dt_rank=256,
)

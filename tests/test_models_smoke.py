"""Per-architecture smoke tests (assignment requirement).

For every assigned arch: instantiate the REDUCED same-family config, run one
forward + one train step on CPU, assert output shapes and no NaNs. Also
checks prefill/decode consistency against the full forward (the serving
path is the paper's deployment mode).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, RunConfig
from repro.launch.train import make_train_state, make_train_step
from repro.nn.models import build_model, input_specs

ARCH_NAMES = sorted(ARCHS)


def _batch(cfg, B=2, S=32, key=0):
    toks = jax.random.randint(jax.random.PRNGKey(key), (B, S + 1), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(key + 1), (B, S, cfg.d_model), jnp.float32)
    return batch, toks


@pytest.fixture(scope="module")
def built(tiny):
    # drop-free capacity for MoE: forward/decode/microbatch comparisons
    # must not differ by which tokens an expert dropped
    return lambda name: tiny(name, drop_free=True)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(built, name):
    cfg, model, params = built(name)
    batch, _ = _batch(cfg)
    logits = model.forward(params, batch["tokens"],
                           frames=batch.get("frames"))
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_decreases_nothing_nan(built, name):
    cfg, _, _ = built(name)
    rcfg = RunConfig(remat="block", learning_rate=1e-3, total_steps=10,
                     warmup_steps=1)
    model = build_model(cfg, rcfg)
    state = make_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model), donate_argnums=(0,))
    batch, _ = _batch(cfg)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    losses = []
    for i in range(3):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.all(np.isfinite(losses)), (name, losses)
    # same batch thrice: loss must drop
    assert losses[-1] < losses[0], (name, losses)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_forward(built, name):
    cfg, model, params = built(name)
    B, S = 2, 16
    batch, toks = _batch(cfg, B=B, S=S)
    frames = batch.get("frames")
    full = model.forward(params, toks, frames=frames)
    cache = model.init_cache(B, S + 4, enc_len=S)
    cache, lg_pre = model.prefill(params, toks[:, :S], cache=cache,
                                  frames=frames)
    np.testing.assert_allclose(np.asarray(lg_pre, np.float32),
                               np.asarray(full[:, S - 1], np.float32),
                               atol=2e-2, rtol=1e-2)
    cache, lg_dec = model.decode_step(params, cache, toks[:, S:S + 1])
    np.testing.assert_allclose(np.asarray(lg_dec, np.float32),
                               np.asarray(full[:, S], np.float32),
                               atol=8e-2, rtol=5e-2)
    assert int(cache["pos"]) == S + 1


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_microbatch_accumulation_matches(built, name):
    """grad accumulation over 2 microbatches == single big batch."""
    cfg, _, _ = built(name)
    batch, _ = _batch(cfg, B=4, S=16)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    out = {}
    for n in (1, 2):
        rcfg = RunConfig(remat="none", microbatch=n, learning_rate=1e-3,
                         total_steps=10, warmup_steps=0)
        model = build_model(cfg, rcfg)
        state = make_train_state(model, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(model))
        new_state, metrics = step(state, batch)
        out[n] = (float(metrics["loss"]),
                  np.asarray(jax.tree.leaves(new_state["params"])[0],
                             np.float32))
    assert abs(out[1][0] - out[2][0]) < 5e-3
    np.testing.assert_allclose(out[1][1], out[2][1], atol=1e-2, rtol=1e-2)


def test_input_specs_cover_all_cells():
    from repro.configs import cells
    from repro.configs.base import SHAPES
    n = 0
    for arch, shape_name, skip in cells():
        cfg = ARCHS[arch]
        spec = input_specs(cfg, SHAPES[shape_name])
        assert "tokens" in spec
        n += 1
    assert n == 40  # 10 archs x 4 shapes

"""flash_attention (custom-vjp) vs naive softmax oracle: fwd + grads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.attention import attn_tp_mode, decode_attention, flash_attention
from repro.nn.sharding import make_ctx

CTX = make_ctx(None)


def naive(q, k, v, causal, offset=0):
    Dh = q.shape[-1]
    Sq, Skv = q.shape[1], k.shape[1]
    s = jnp.einsum("bqgrd,bkgd->bgrqk", q, k) * (Dh ** -0.5)
    if causal:
        mask = (jnp.arange(Skv)[None, :] <= offset + jnp.arange(Sq)[:, None])
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bgrqk,bkgd->bqgrd", p, v)


def _rand(shapes, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), len(shapes))
    return [jax.random.normal(k, s, jnp.float32) for k, s in zip(keys, shapes)]


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("qc,kvc", [(8, 8), (16, 4), (64, 64), (13, 7)])
def test_flash_matches_naive(causal, qc, kvc):
    B, S, G, R, Dh = 2, 64, 2, 3, 16
    q, k, v = _rand([(B, S, G, R, Dh), (B, S, G, Dh), (B, S, G, Dh)])
    out = flash_attention(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kvc,
                          ctx=CTX, mode="kv")
    ref = naive(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_flash_grads_match_naive(causal):
    B, S, G, R, Dh = 2, 32, 2, 2, 8
    q, k, v = _rand([(B, S, G, R, Dh), (B, S, G, Dh), (B, S, G, Dh)], seed=3)

    def loss_flash(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(
            q, k, v, causal=causal, q_chunk=8, kv_chunk=8, ctx=CTX,
            mode="kv")))

    def loss_naive(q, k, v):
        return jnp.sum(jnp.sin(naive(q, k, v, causal)))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-5, rtol=5e-5)


def test_flash_bias_offset_prefix():
    """bias_offset shifts causality: q tokens attend to an existing prefix."""
    B, G, R, Dh = 1, 1, 1, 8
    S_pre, S_new = 8, 8
    q_all, k_all, v_all = _rand([(B, S_pre + S_new, G, R, Dh),
                                 (B, S_pre + S_new, G, Dh),
                                 (B, S_pre + S_new, G, Dh)], seed=5)
    full = naive(q_all, k_all, v_all, causal=True)
    out = flash_attention(q_all[:, S_pre:], k_all, v_all, causal=True,
                          q_chunk=4, kv_chunk=4, ctx=CTX, mode="kv",
                          bias_offset=S_pre)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, S_pre:]),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_matches_naive_row():
    B, S, G, R, Dh = 2, 24, 2, 2, 8
    q, k, v = _rand([(B, 1, G, R, Dh), (B, S, G, Dh), (B, S, G, Dh)], seed=7)
    pos = 17
    # decode caches are heads-major (B, G, S, Dh) — EXPERIMENTS.md §Perf iter C
    k_hm, v_hm = jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2)
    out = decode_attention(q, k_hm, v_hm, jnp.asarray(pos), CTX, "kv")
    ref = naive(q, k[:, :pos], v[:, :pos], causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_tp_mode_selection():
    assert attn_tp_mode(128, 8, 16) == "rep"      # llama3
    assert attn_tp_mode(32, 4, 16) == "expand"    # yi-9b
    assert attn_tp_mode(96, 8, 16) == "expand"    # nemotron
    assert attn_tp_mode(16, 16, 16) == "kv"       # whisper/moonshot
    assert attn_tp_mode(32, 32, 16) == "kv"       # zamba2
    # llama4: 40 heads / 8 kv — nothing divides 16 -> replicated attention
    # (documented fallback; DESIGN.md §Arch-applicability)
    assert attn_tp_mode(40, 8, 16) == "none"
    assert attn_tp_mode(12, 3, 16) == "none"
    assert attn_tp_mode(8, 8, 1) == "kv"

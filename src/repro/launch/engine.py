"""Continuous-batching serving engine: slot scheduler + scan-fused decode.

The paper's deployment story is PTQ inference through the PoFx datapath;
this module is the system that serves it under real traffic instead of the
old one-shot fixed-batch driver. Design (DESIGN.md §7):

* **Slots.** A fixed-slot batch of ``n_slots`` sequences shares one donated
  decode cache whose ``pos`` leaf is a per-slot (B,) length vector. Slots
  mask independently: ``decode_step`` rotates, writes KV and masks
  attention per slot, so requests of different ages coexist in one batch.
* **Admission.** A request is prefilled alone (batch 1, optionally padded
  to a length bucket to bound recompilation) and its cache scattered into
  a free slot along every leaf's batch axis (``LM.cache_logical`` names
  it). The first token is sampled from the prefill logits.
* **Decode.** ``chunk`` steps run as ONE jitted ``lax.scan`` — no
  per-step Python dispatch. Per-slot stopping (EOS / max-new-tokens)
  freezes a finished slot inside the chunk: its pos stops advancing and it
  emits pad tokens until the host retires it and admits the next request.
* **Sampling.** Greedy (temperature 0), temperature, and top-k compose
  per slot from (B,) parameter vectors; each slot folds its own PRNG key
  with its position, so a request's sample stream is reproducible
  regardless of batch composition — eviction + re-admission resumes the
  identical stream.
* **Eviction.** ``evict`` returns a running request to the pending queue
  with its generated prefix folded into the context; re-admission prefills
  prompt+prefix and continues. Scheduler invariants are tested in
  tests/test_engine.py.

``use_kernel`` is decided by the ``LM`` the engine wraps
(``build_model(..., use_kernel=True)``), so quantized serving exercises
the fused Pallas PoFx/FxP kernels end to end. So is the KV-cache format
(``build_model(..., kv_spec=...)``, DESIGN.md §8): with a quantized cache
the slot cache's "k"/"v" leaves hold byte-wide codes next to static
per-channel scale leaves, and the scatter/evict/resume machinery below is
layout-agnostic — admission scatters code+scale leaves along the batch
axis ``LM.cache_logical`` names, and eviction's re-prefill regenerates the
identical codes (static scales + fake-quant prefill), so the
resume-identical guarantee survives the lossy cache.

So, finally, is tensor parallelism (DESIGN.md §9): a model built over the
1-D ``("tp",)`` serving mesh (``build_model(..., mesh=make_tp_mesh(N))``)
makes the engine device_put parameters and the slot cache sharded —
attention heads, MLP hidden, experts, and the KV cache's head axis (codes
AND static scales) split over tp — and run prefill + the chunked decode
scan inside ``shard_map`` with the model's ``manual_tp`` twin (explicit
one-psum-per-block collectives). Tokens, slot keys, sampling params and
``pos`` stay replicated, so every scheduler decision below — admit, evict,
resume, per-slot stopping — is device-count-agnostic and the served token
streams are the single-device streams.

**Paged mode** (``paged=True``, DESIGN.md §10): the per-slot dense cache is
replaced by one flat pool of fixed-size token pages plus per-slot block
tables; ``launch.paging.PagedKVManager`` owns allocation, refcounts and the
radix prefix index on the host. Admission matches the context against the
index and prefills only the unshared suffix (the shared prefix — system
prompts, resumed generations — is already resident); eviction registers
the sequence's pages in the index and drops its references, so resume
re-attaches surviving pages and re-prefills exactly one token. The token
streams stay identical to the dense engine's (the paged differential
contract, tests/test_paged_cache.py): page contents are a deterministic
function of the token prefix under the pool's global static scales, and
the suffix prefill attends to [shared prefix ; suffix] with the same
kv-chunk boundaries a dense full prefill would use.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["SamplingParams", "Request", "RequestState", "ServeEngine",
           "sample_tokens"]

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs; all compose per slot inside the scan."""
    temperature: float = 0.0     # 0 = greedy (argmax)
    top_k: int = 0               # 0 = no truncation


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    prompt: np.ndarray           # (P,) int token ids
    max_new: int = 32            # tokens to generate (incl. prefill-sampled)
    sampling: SamplingParams = SamplingParams()
    arrival: float = 0.0         # virtual time (decode steps) of arrival


@dataclasses.dataclass
class RequestState:
    req: Request
    context: np.ndarray          # tokens to prefill (prompt, +prefix on resume)
    slot: int = -1
    out: List[int] = dataclasses.field(default_factory=list)
    finish_reason: Optional[str] = None   # "eos" | "length"
    admitted_at: float = -1.0
    finished_at: float = -1.0
    n_evictions: int = 0

    @property
    def done(self) -> bool:
        return self.finish_reason is not None


def sample_tokens(logits: jax.Array, keys: jax.Array, temps: jax.Array,
                  topks: jax.Array, use_topk: bool = True) -> jax.Array:
    """Pluggable per-slot sampling: greedy / temperature / top-k.

    logits (B, V); keys (B,) PRNG keys; temps (B,) float (0 = greedy);
    topks (B,) int (0 = full distribution). Greedy slots ignore their key,
    so free slots can carry stale keys safely. ``use_topk=False`` (a
    static promise that every topk is 0) skips the O(V log V) sort — the
    engine sets it per chunk so temperature-only serving never pays for
    top-k in the hot loop.
    """
    V = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1)
    if use_topk:
        k = jnp.clip(topks, 1, V)
        sorted_lg = jnp.sort(logits, axis=-1)         # ascending
        kth = jnp.take_along_axis(sorted_lg, (V - k)[:, None], axis=-1)
        filt = jnp.where((topks[:, None] > 0) & (logits < kth), NEG_INF,
                         logits)
    else:
        filt = logits
    scaled = filt / jnp.maximum(temps, 1e-6)[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled)
    return jnp.where(temps > 0, sampled, greedy).astype(jnp.int32)


class ServeEngine:
    """Slot-based continuous batching over one ``LM`` + quantized params.

    Host side owns scheduling (pending queue, slot occupancy, token
    streams); device side owns the batch state (cache, last tokens, slot
    keys). Each ``step`` call launches one jitted scan of ``chunk`` decode
    steps; admission happens between chunks.
    """

    def __init__(self, model, params, *, n_slots: int = 4, max_len: int = 512,
                 eos_id: Optional[int] = None, pad_id: int = 0,
                 chunk: int = 8, prompt_bucket: int = 1, seed: int = 0,
                 paged: bool = False, page_size: int = 16,
                 n_pages: Optional[int] = None):
        if model.cfg.family == "encdec":
            raise NotImplementedError(
                "encdec serving needs per-request encoder frames; use the "
                "one-shot path in repro.launch.serve")
        if prompt_bucket > 1 and model.cfg.family in ("ssm", "hybrid"):
            raise ValueError(
                "prompt_bucket > 1 right-pads prefill, which pollutes SSM "
                "recurrent state; use exact-length prefill (bucket 1)")
        if n_slots < 1 or chunk < 1:
            raise ValueError(
                f"need n_slots >= 1 and chunk >= 1, got {n_slots}/{chunk}")
        self.model, self.params = model, params
        self.n_slots, self.max_len = int(n_slots), int(max_len)
        self.eos_id = eos_id
        self.pad_id = int(pad_id)
        self.chunk = int(chunk)
        self.prompt_bucket = max(1, int(prompt_bucket))
        self.paged = bool(paged)

        # Tensor parallelism: a model built over the ("tp",) serving mesh
        # serves sharded. ``_mm`` is the model the jitted device functions
        # call — the manual_tp twin inside shard_map, the model itself
        # otherwise. Scheduler state below never looks at tp.
        self.tp = model.tp_size
        self._mm = model.manual_tp() if self.tp > 1 else model
        self._mesh = model.ctx.mesh if self.tp > 1 else None

        if self.paged:
            # Paged KV cache (DESIGN.md §10): one flat page pool + per-slot
            # block tables; the host-side manager owns allocation/refcounts
            # and the radix prefix index. Default pool sizing matches the
            # dense cache's capacity (every slot can hold max_len tokens)
            # plus per-slot headroom for copy-on-write and index retention.
            from repro.core.policy import format_spec
            from .paging import PagedKVManager
            self.page_size = int(page_size)
            self.max_pages = -(-self.max_len // self.page_size)
            if n_pages is None:
                n_pages = n_slots * self.max_pages + n_slots + 1
            self.n_pages = int(n_pages)
            self.cache = model.init_paged_cache(
                n_slots, max_len, n_pages=self.n_pages,
                page_size=self.page_size)
            kv = (self.cache["kv"]["moe"] if "moe" in self.cache["kv"]
                  else self.cache["kv"])
            # pages are shareable only between consumers of one cache
            # format: the index keys on the spec string (or raw dtype)
            spec_key = (format_spec(model.kv_spec) if model.kv_spec
                        else f"raw:{kv['k'].dtype}")
            self._pager = PagedKVManager(self.n_pages, self.page_size,
                                         self.max_pages, spec_key)
            self._slot_pos = np.zeros(n_slots, np.int64)
            self._cache_log_flat = jax.tree_util.tree_flatten(
                model.paged_cache_logical(),
                is_leaf=lambda x: isinstance(x, tuple))[0]
        else:
            self.cache = model.init_cache(n_slots, max_len)
            self.cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
            self._cache_log_flat = jax.tree_util.tree_flatten(
                model.cache_logical(),
                is_leaf=lambda x: isinstance(x, tuple))[0]
        n_leaves = len(jax.tree_util.tree_leaves(self.cache))
        if n_leaves != len(self._cache_log_flat):
            # scatter zips cache leaves against logical axes positionally;
            # a silent mismatch (e.g. a cache layout that grew leaves —
            # quantized caches add scale leaves — without a cache_logical
            # update) would mis-scatter instead of erroring
            raise ValueError(
                f"cache has {n_leaves} leaves but cache_logical names "
                f"{len(self._cache_log_flat)}; LM.init_cache and "
                "LM.cache_logical disagree")
        if self.tp > 1:
            self._param_specs = model.param_tp_specs(params)
            self._cache_specs = model.cache_tp_specs(self.cache)
            if not self.paged:
                self._small_specs = model.cache_tp_specs(
                    jax.eval_shape(lambda: model.init_cache(1, self.max_len)))
            put = lambda tree, specs: jax.device_put(
                tree, jax.tree.map(
                    lambda s: NamedSharding(self._mesh, s), specs))
            self.params = put(self.params, self._param_specs)
            self.cache = put(self.cache, self._cache_specs)
        self._tok = jnp.full((n_slots, 1), self.pad_id, jnp.int32)
        self._base_key = jax.random.PRNGKey(seed)
        # placeholder slot keys (replaced at admit; fold stream disjoint
        # from per-request keys, which fold non-negative rids)
        filler = jax.random.fold_in(self._base_key, np.uint32(0xFFFFFFFF))
        self._keys = jnp.stack(
            [jax.random.fold_in(filler, i) for i in range(n_slots)])

        # host-side scheduler state
        self._slot_rid = np.full(n_slots, -1, np.int64)
        self._states: Dict[int, RequestState] = {}
        self._pending: Deque[int] = deque()
        self._done_box: List[RequestState] = []
        self.clock = 0.0              # virtual time = decode steps executed
        self.prefill_time = 0.0
        self.decode_time = 0.0
        self.decode_steps = 0
        self.n_prefill_sampled = 0    # tokens sampled from prefill logits
        #   (one per admission, so one per request plus one per eviction —
        #    the exact complement of decode-generated tokens)

        self._chunk_fn = jax.jit(
            self._chunk_wrap,
            static_argnames=("steps", "eos", "pad", "greedy_only",
                             "topk_any"),
            donate_argnums=(1,))
        if self.paged:
            self._prefill_paged_fn = jax.jit(
                self._prefill_paged_wrap, static_argnames=("prefix_len",),
                donate_argnums=(1,))
            self._copy_page_fn = jax.jit(self._copy_page_impl,
                                         donate_argnums=(0,))
        else:
            self._scatter_fn = jax.jit(self._scatter_impl, donate_argnums=(0,))
            self._prefill_fn = jax.jit(self._prefill_wrap, donate_argnums=(1,))
        self._sample_fn = jax.jit(sample_tokens)

    # -- scheduler (host) ----------------------------------------------------

    @property
    def free_slots(self) -> List[int]:
        return [b for b in range(self.n_slots) if self._slot_rid[b] < 0]

    @property
    def active_rids(self) -> List[int]:
        return [int(r) for r in self._slot_rid if r >= 0]

    @property
    def pending_rids(self) -> List[int]:
        return list(self._pending)

    def submit(self, req: Request) -> None:
        if req.rid in self._states:
            raise ValueError(f"duplicate request id {req.rid}")
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if prompt.size >= self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt length {prompt.size} >= "
                f"max_len {self.max_len}")
        if req.max_new < 1:
            raise ValueError(f"request {req.rid}: max_new must be >= 1")
        self._states[req.rid] = RequestState(req=req, context=prompt)
        self._pending.append(req.rid)

    def evict(self, rid: int) -> None:
        """Preempt a running request back to the head of the pending queue.

        Its generated prefix folds into the context, so re-admission
        prefills prompt+prefix and resumes the identical sample stream
        (slot keys fold with absolute position).
        """
        st = self._states[rid]
        if st.slot < 0 or st.done:
            raise ValueError(f"request {rid} is not running")
        st.context = np.concatenate(
            [np.asarray(st.req.prompt, np.int32).reshape(-1),
             np.asarray(st.out, np.int32)])
        if self.paged:
            # register the sequence's pages in the prefix index, then drop
            # its references: surviving pages make resume a one-token
            # prefill (the index match re-attaches them), and a genuinely
            # evicted (reclaimed) page just re-prefills like dense mode.
            # Valid tokens = written KV positions = the slot's pos (the
            # final sampled token was emitted but its KV never written).
            self._release_slot_pages(rid, st)
        self._slot_rid[st.slot] = -1
        st.slot = -1
        st.n_evictions += 1
        self._pending.appendleft(rid)

    def admit_ready(self) -> int:
        """Admit arrived pending requests into free slots; returns count.

        Scans the whole queue (FIFO among arrived), not just the head: a
        manually-submitted queue need not be arrival-ordered, and a
        not-yet-arrived head must not block an already-arrived request
        behind it (that would livelock ``run``'s idle fast-forward).
        """
        n = 0
        while self.free_slots:
            rid = next((r for r in self._pending
                        if self._states[r].req.arrival <= self.clock), None)
            if rid is None:
                break
            self._pending.remove(rid)
            self._admit(rid, self.free_slots[0])
            n += 1
        return n

    def _eff_max_new(self, st: RequestState) -> int:
        """max_new clamped so decode never writes past max_len."""
        room = self.max_len - int(np.asarray(st.req.prompt).size)
        return min(st.req.max_new, room)

    def _admit_paged(self, rid: int, slot: int) -> None:
        """Paged admission: match the prefix index, attach shared pages,
        prefill only the unshared suffix through the page pool.

        The host manager plans everything (borrowed pages, copy-on-write
        of a mid-page boundary, fresh allocations); the device executes
        the plan: CoW pool copies, the slot's block-table row, then a
        batch-1 suffix prefill whose attention spans [shared prefix ;
        suffix] — sampled logits match a dense full prefill's, so the
        admission is stream-identical to the dense engine's.
        """
        st = self._states[rid]
        ctx = st.context
        P = int(ctx.size)
        Pb = min(-(-P // self.prompt_bucket) * self.prompt_bucket,
                 self.max_len)
        t0 = time.perf_counter()
        # prompt_bucket > 1 means the operator asked for bounded prefill
        # compile variants — page-align the prefix hit too, since each
        # distinct prefix_len is a fresh compile (exact-length serving,
        # bucket 1, keeps token-granular sharing and recompiles per
        # length, exactly like dense prefill does)
        plan = self._pager.admit(rid, ctx.tolist(), Pb,
                                 page_align=self.prompt_bucket > 1)
        prefix_len = int(plan.prefix_len)
        for src, dst in plan.copies:
            self.cache = self._copy_page_fn(self.cache,
                                            jnp.asarray(src, jnp.int32),
                                            jnp.asarray(dst, jnp.int32))
        self.cache["pages"] = self.cache["pages"].at[slot].set(
            jnp.asarray(plan.table))
        n_suffix = P - prefix_len
        padded = np.full((1, Pb - prefix_len), self.pad_id, np.int32)
        padded[0, :n_suffix] = ctx[prefix_len:]
        self.cache, logits = self._prefill_paged_fn(
            self.params, self.cache, jnp.asarray(padded),
            jnp.asarray(slot, jnp.int32), jnp.asarray(n_suffix, jnp.int32),
            prefix_len=prefix_len)
        # index the prompt's pages so concurrent/later requests with the
        # same system prompt skip its prefill (content is final: writes
        # past P only ever touch offsets beyond the registered valid run)
        self._pager.register(rid, ctx.tolist(), P)
        self._slot_pos[slot] = P
        self._finish_admit(rid, slot, P, logits, t0)

    def _admit(self, rid: int, slot: int) -> None:
        if self.paged:
            return self._admit_paged(rid, slot)
        st = self._states[rid]
        ctx = st.context
        P = int(ctx.size)
        # bucket-rounded length clamped to the cache: prefill writes Pb KV
        # positions, and a resumed context may sit close to max_len
        Pb = min(-(-P // self.prompt_bucket) * self.prompt_bucket,
                 self.max_len)
        padded = np.full((1, Pb), self.pad_id, np.int32)
        padded[0, :P] = ctx
        t0 = time.perf_counter()
        small = self.model.init_cache(1, self.max_len)
        small = self._seed_kv_scales(small, slot)
        # bucket 1 means exact-length prompts: take the length=None path so
        # SSM/hybrid (which refuse right-padded prefill) serve too.
        length = None if Pb == P else jnp.asarray(P, jnp.int32)
        small, logits = self._prefill_fn(
            self.params, small, jnp.asarray(padded), length)
        self.cache = self._scatter_fn(self.cache, small,
                                      jnp.asarray(slot, jnp.int32))
        self._finish_admit(rid, slot, P, logits, t0)

    def _finish_admit(self, rid: int, slot: int, P: int, logits,
                      t0: float) -> None:
        """Shared admission tail: sample the first token from the prefill
        logits (key folds the ABSOLUTE position P-1, so paged and dense
        admissions draw the identical stream), publish slot state, retire
        immediately on eos/length."""
        st = self._states[rid]
        key = jax.random.fold_in(self._base_key, rid)
        st0 = st.req.sampling
        tok0 = self._sample_fn(
            logits, jax.random.fold_in(key, P - 1)[None],
            jnp.asarray([st0.temperature], jnp.float32),
            jnp.asarray([st0.top_k], jnp.int32))
        tok0 = int(tok0[0])
        self._tok = self._tok.at[slot, 0].set(tok0)
        self._keys = self._keys.at[slot].set(key)
        jax.block_until_ready(self._tok)
        self.prefill_time += time.perf_counter() - t0

        self._slot_rid[slot] = rid
        st.slot = slot
        if st.admitted_at < 0:
            st.admitted_at = self.clock
        st.out.append(tok0)
        self.n_prefill_sampled += 1
        if self.eos_id is not None and tok0 == self.eos_id:
            self._finish(rid, "eos")
        elif len(st.out) >= self._eff_max_new(st):
            self._finish(rid, "length")

    def _release_slot_pages(self, rid: int, st: RequestState) -> None:
        """Index the slot's pages (full pages + partial tail) for future
        prefix hits, return the sequence's references to the allocator,
        and point the slot's block-table row at the garbage page so the
        retired slot's zombie decode writes (it still rides in the batch
        until the next admission) cannot touch a live page."""
        slot = st.slot
        tokens = np.concatenate(
            [np.asarray(st.req.prompt, np.int32).reshape(-1),
             np.asarray(st.out, np.int32)])
        self._pager.suspend(rid, tokens.tolist(), int(self._slot_pos[slot]))
        self.cache["pages"] = self.cache["pages"].at[slot].set(
            jnp.zeros((self.max_pages,), jnp.int32))

    def _finish(self, rid: int, reason: str) -> None:
        st = self._states[rid]
        st.finish_reason = reason
        st.finished_at = self.clock
        if st.slot >= 0:
            if self.paged:
                self._release_slot_pages(rid, st)
            self._slot_rid[st.slot] = -1
            st.slot = -1
        self._done_box.append(st)

    # -- device chunk --------------------------------------------------------

    def _prefill_wrap(self, params, cache, tokens, length):
        """Prefill, shard_map-wrapped when serving tensor-parallel.

        Inside the shard_map every device prefills with its local weight /
        KV-head shard (one psum per block); tokens, length and logits are
        replicated. ``length`` may be None (exact-length prompts) — an
        empty pytree, which shard_map broadcasts a spec over harmlessly.
        """
        if self.tp == 1:
            return self.model.prefill(params, tokens, cache=cache,
                                      length=length)
        from repro.nn.sharding import shard_map_compat
        mm = self._mm
        fn = lambda p, c, t, l: mm.prefill(p, t, cache=c, length=l)
        rep = P()
        return shard_map_compat(
            fn, self._mesh,
            in_specs=(self._param_specs, self._small_specs, rep, rep),
            out_specs=(self._small_specs, rep),
        )(params, cache, tokens, length)

    def _prefill_paged_wrap(self, params, cache, tokens, slot, length, *,
                            prefix_len: int):
        """Paged suffix prefill, shard_map-wrapped when tensor-parallel:
        the page pools ride in/out as head shards, the block tables / slot
        / length / logits replicate. ``prefix_len`` is static (it fixes
        gather sizes and the attention bias offset), so each distinct
        shared-prefix length compiles once."""
        if self.tp == 1:
            return self.model.prefill_paged(params, tokens, cache=cache,
                                            slot=slot, length=length,
                                            prefix_len=prefix_len)
        from repro.nn.sharding import shard_map_compat
        mm = self._mm
        fn = lambda p, c, t, s, l: mm.prefill_paged(
            p, t, cache=c, slot=s, length=l, prefix_len=prefix_len)
        rep = P()
        return shard_map_compat(
            fn, self._mesh,
            in_specs=(self._param_specs, self._cache_specs, rep, rep, rep),
            out_specs=(self._cache_specs, rep),
        )(params, cache, tokens, slot, length)

    def _copy_page_impl(self, cache, src, dst):
        """Copy page ``src`` -> ``dst`` in every pool code leaf (the
        device half of copy-on-write; scales are global per layer, nothing
        to copy). The pool axis is found from the paged logical tree, so
        moe's extra layer-stacking dims need no special-casing."""
        flat, treedef = jax.tree_util.tree_flatten(cache)
        out = []
        for leaf, ax in zip(flat, self._cache_log_flat):
            if "kv_pages" not in ax:
                out.append(leaf)
                continue
            axis = ax.index("kv_pages")
            page = jax.lax.dynamic_index_in_dim(leaf, src, axis,
                                                keepdims=False)
            out.append(jax.lax.dynamic_update_index_in_dim(
                leaf, page, dst, axis))
        return jax.tree_util.tree_unflatten(treedef, out)

    def _chunk_wrap(self, params, cache, tok, done, n_gen, keys, temps,
                    topks, max_new, *, steps: int, eos: int, pad: int,
                    greedy_only: bool, topk_any: bool):
        """The scan-fused chunk, shard_map-wrapped when tensor-parallel.

        The whole ``steps``-long decode scan runs inside ONE shard_map:
        params and the slot cache stay resident as shards, the per-slot
        token/done/pos/sampling state is replicated (every device runs the
        identical sampler on identical psum'd logits), so the emitted
        tokens are bit-identical to the tp=1 scan's by construction of the
        replicated compute — the property tests/test_tp_engine.py pins.
        """
        impl = functools.partial(self._chunk_impl, steps=steps, eos=eos,
                                 pad=pad, greedy_only=greedy_only,
                                 topk_any=topk_any)
        if self.tp == 1:
            return impl(params, cache, tok, done, n_gen, keys, temps, topks,
                        max_new)
        from repro.nn.sharding import shard_map_compat
        rep = P()
        return shard_map_compat(
            impl, self._mesh,
            in_specs=(self._param_specs, self._cache_specs,
                      rep, rep, rep, rep, rep, rep, rep),
            out_specs=(self._cache_specs, rep, rep, rep, rep),
        )(params, cache, tok, done, n_gen, keys, temps, topks, max_new)

    def _seed_kv_scales(self, small, slot: int):
        """Copy the target slot's static KV scale leaves into the batch-1
        prefill cache. Scales are calibration state (per-model constants,
        DESIGN.md §8), not per-request state: init_cache resets them to
        1.0, so without this an operator's calibrated scales would drive
        neither the admit prefill nor — after the scatter writes the
        batch-1 leaves back — any later decode on that slot."""
        if self.model.kv_spec is None:
            return small
        flat, treedef = jax.tree_util.tree_flatten_with_path(small)
        big_flat = jax.tree_util.tree_flatten(self.cache)[0]
        out = []
        for (path, s), b, ax in zip(flat, big_flat, self._cache_log_flat):
            name = getattr(path[-1], "key", None)
            if isinstance(name, str) and name.endswith("_scale"):
                axis = ax.index("batch")
                s = jax.lax.dynamic_slice_in_dim(b, slot, 1, axis=axis)
            out.append(s)
        return jax.tree_util.tree_unflatten(treedef, out)

    def _scatter_impl(self, big, small, slot):
        """Write a batch-1 prefilled cache into slot ``slot`` of the big
        cache, leaf by leaf along the axis ``cache_logical`` names "batch"
        (pos, logical (), is per-slot scalar)."""
        big_flat, treedef = jax.tree_util.tree_flatten(big)
        small_flat = jax.tree_util.tree_flatten(small)[0]
        out = []
        for b, s, ax in zip(big_flat, small_flat, self._cache_log_flat):
            if ax == ():
                out.append(b.at[slot].set(
                    jnp.ravel(jnp.asarray(s))[0].astype(b.dtype)))
            else:
                axis = ax.index("batch")
                upd = jax.lax.index_in_dim(s, 0, axis=axis, keepdims=False)
                out.append(jax.lax.dynamic_update_index_in_dim(
                    b, upd.astype(b.dtype), slot, axis))
        return jax.tree_util.tree_unflatten(treedef, out)

    def _chunk_impl(self, params, cache, tok, done, n_gen, keys, temps,
                    topks, max_new, *, steps: int, eos: int, pad: int,
                    greedy_only: bool, topk_any: bool):
        """``steps`` decode iterations as one lax.scan; per-slot stopping.

        A slot that emits EOS (or hits max_new) freezes: pos stops
        advancing, later emissions are pad. The emitted-token semantics
        mirror the host loop in ``step`` exactly. ``greedy_only`` (static,
        host-known per chunk) skips the top-k sort + categorical draw in
        the hot loop when every live slot has temperature 0 — argmax is
        exactly what sample_tokens returns there.
        """
        model = self._mm    # the manual_tp twin when serving tensor-parallel

        def body(carry, _):
            cache, tok, done, n_gen = carry
            pos = cache["pos"]
            cache, logits = model.decode_step(params, cache, tok)
            if greedy_only:
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                step_keys = jax.vmap(jax.random.fold_in)(keys, pos)
                nxt = sample_tokens(logits, step_keys, temps, topks,
                                    use_topk=topk_any)
            stop = (nxt == eos) | (n_gen + 1 >= max_new)
            nxt = jnp.where(done, pad, nxt)
            n_gen = jnp.where(done, n_gen, n_gen + 1)
            new_done = done | stop
            cache = dict(cache, pos=jnp.where(done, pos, pos + 1))
            return (cache, nxt[:, None], new_done, n_gen), nxt

        (cache, tok, done, n_gen), toks = jax.lax.scan(
            body, (cache, tok, done, n_gen), None, length=steps)
        return cache, tok, done, n_gen, toks      # toks: (steps, B)

    # -- chunk driver (host) -------------------------------------------------

    def step(self, steps: Optional[int] = None) -> List[RequestState]:
        """Run one scan-fused chunk; returns requests finished in it.

        The chunk is capped by the largest remaining per-slot budget so a
        tail chunk doesn't scan steps in which every slot is frozen — but
        the cap rounds up to a power of two, because ``steps`` is a static
        jit argument and every distinct value recompiles the whole scan:
        pow2 rounding bounds wasted tail work below 2x useful steps while
        bounding compile variants at log2(chunk) instead of chunk.
        """
        steps = int(steps or self.chunk)
        B = self.n_slots
        live = self._slot_rid >= 0
        if not live.any():
            return []
        n_gen = np.zeros(B, np.int32)
        temps = np.zeros(B, np.float32)
        topks = np.zeros(B, np.int32)
        max_new = np.full(B, np.iinfo(np.int32).max, np.int32)
        for b, rid in enumerate(self._slot_rid):
            if rid < 0:
                continue
            st = self._states[rid]
            n_gen[b] = len(st.out)
            temps[b] = st.req.sampling.temperature
            topks[b] = st.req.sampling.top_k
            max_new[b] = self._eff_max_new(st)
        eos = self.eos_id if self.eos_id is not None else -1
        rem = int((max_new[live] - n_gen[live]).max())
        steps = min(steps, 1 << max(rem - 1, 0).bit_length())

        if self.paged:
            # allocate page coverage for every live slot's worst-case chunk
            # advance BEFORE the scan runs device-side (allocation is host
            # state; a mid-chunk page-boundary crossing cannot call out)
            for b, rid in enumerate(self._slot_rid):
                if rid < 0:
                    continue
                row = self._pager.ensure(
                    rid, min(int(self._slot_pos[b]) + steps, self.max_len))
                if row is not None:
                    self.cache["pages"] = self.cache["pages"].at[b].set(
                        jnp.asarray(row))

        t0 = time.perf_counter()
        self.cache, self._tok, _, _, toks = self._chunk_fn(
            self.params, self.cache, self._tok, jnp.asarray(~live),
            jnp.asarray(n_gen), self._keys, jnp.asarray(temps),
            jnp.asarray(topks), jnp.asarray(max_new),
            steps=steps, eos=int(eos), pad=self.pad_id,
            greedy_only=bool((temps == 0).all()),
            topk_any=bool((topks > 0).any()))
        toks = np.asarray(toks)                  # blocks; (steps, B)
        self.decode_time += time.perf_counter() - t0
        self.decode_steps += steps
        self.clock += steps

        finished: List[RequestState] = []
        for b, rid in enumerate(self._slot_rid):
            if rid < 0:
                continue
            st = self._states[rid]
            limit = self._eff_max_new(st)
            for s in range(steps):
                t = int(toks[s, b])
                st.out.append(t)
                if self.paged:
                    # mirror the device: pos advances once per emitted
                    # token (the final-token step advances, then freezes),
                    # and must be current before _finish releases pages
                    self._slot_pos[b] += 1
                if self.eos_id is not None and t == self.eos_id:
                    self._finish(rid, "eos")
                    break
                if len(st.out) >= limit:
                    self._finish(rid, "length")
                    break
            if st.done:
                finished.append(st)
        return finished

    def run(self, requests: Sequence[Request],
            chunk: Optional[int] = None) -> List[RequestState]:
        """Serve a workload to completion; returns states sorted by rid.

        Arrival times are in decode steps of virtual time; the clock
        advances by each chunk's step count and fast-forwards over idle
        gaps, so arrival mixes are reproducible independent of wall speed.
        """
        for r in sorted(requests, key=lambda r: (r.arrival, r.rid)):
            self.submit(r)
        t0 = time.perf_counter()
        while self._pending or self.active_rids:
            self.admit_ready()
            if not self.active_rids:
                nxt = min(self._states[rid].req.arrival
                          for rid in self._pending)
                self.clock = max(self.clock, nxt)
                continue
            self.step(chunk)
        self.total_time = time.perf_counter() - t0
        done, self._done_box = self._done_box, []
        return sorted(done, key=lambda s: s.req.rid)

    # -- reporting -----------------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        gen = sum(len(s.out) for s in self._states.values())
        # one token per *admission* comes from prefill logits (so one per
        # request plus one per eviction/resume); the rest are decode steps
        n_dec = gen - self.n_prefill_sampled
        out = {
            "requests": len(self._states),
            "generated_tokens": gen,
            "prefill_sampled_tokens": self.n_prefill_sampled,
            "decode_tokens": n_dec,
            "decode_steps": self.decode_steps,
            "prefill_time_s": self.prefill_time,
            "decode_time_s": self.decode_time,
            "decode_tok_per_s": n_dec / self.decode_time
            if self.decode_time else 0.0,
        }
        if self.paged:
            # prefix_hit_tokens = prefill tokens skipped via shared pages;
            # resident_pages counts live pool pages (slots + index)
            out.update(self._pager.stats())
        return out

"""MoE dispatch invariants + oracle comparison."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke
from repro.nn import moe
from repro.nn.layers import param_value
from repro.nn.sharding import make_ctx

CTX = make_ctx(None)


@pytest.fixture(scope="module")
def setup():
    cfg = smoke(ARCHS["moonshot-v1-16b-a3b"])
    cfg = dataclasses.replace(cfg, n_shared_experts=0, capacity_factor=100.0)
    p = moe.moe_init(jax.random.PRNGKey(0), cfg)
    return cfg, p


def moe_oracle(p, x, cfg):
    """Dense per-token oracle: route, run every token through its top-k
    experts with no capacity dropping."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    act = jax.nn.silu
    out = jnp.zeros_like(xt)
    for e in range(cfg.n_experts):
        g = act(xt @ p["wg"][e]) * (xt @ p["wu"][e])
        y_e = g @ p["wo"][e]
        for j in range(cfg.top_k):
            w = jnp.where(idx[:, j] == e, gates[:, j], 0.0)
            out = out + w[:, None] * y_e
    return out.reshape(B, S, d)


def test_moe_matches_oracle_no_drops(setup):
    cfg, p = setup
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    got = moe.moe_forward(p, x, cfg, CTX)
    ref = moe_oracle(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_moe_capacity_drops_reduce_output(setup):
    cfg, p = setup
    tight = dataclasses.replace(cfg, capacity_factor=0.25)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
    full = moe.moe_forward(p, x, cfg, CTX)
    dropped = moe.moe_forward(p, x, tight, CTX)
    # dropping must change (reduce) some outputs but never produce NaN
    assert bool(jnp.all(jnp.isfinite(dropped)))
    assert float(jnp.max(jnp.abs(full - dropped))) > 0


def test_moe_decode_never_drops(setup):
    cfg, p = setup
    tight = dataclasses.replace(cfg, capacity_factor=0.01)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 1, cfg.d_model))
    got = moe.moe_forward(p, x, tight, CTX)       # S==1: drop-free
    ref = moe_oracle(p, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_router_aux_loss_penalizes_imbalance(setup):
    cfg, p = setup
    # positive activations so a one-column router concentrates all mass on
    # expert 0 for every token
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(4),
                                  (2, 32, cfg.d_model)))
    p_imb = dict(p, router=jnp.zeros_like(p["router"]).at[:, 0].set(100.0))
    l_imb = float(moe.router_aux_loss(p_imb, x, cfg))
    l_real = float(moe.router_aux_loss(p, x, cfg))
    assert l_imb > l_real
    # all mass on one expert: aux = E * f_0 * P_0 with f_0 ~ 1/k, P_0 ~ 1
    assert l_imb > cfg.n_experts / cfg.top_k * 0.5

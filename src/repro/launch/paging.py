"""Paged KV-cache block management: pages, refcounts, prefix sharing.

The dense engine allocates one ``(n_slots, max_len)`` quantized KV cache —
every short request pays for ``max_len`` positions of HBM and every
evict -> resume pays a full re-prefill. This module is the host half of the
paged alternative (DESIGN.md §10): the device holds one flat *pool* of
fixed-size token pages (quantized codes next to the static per-channel
scale leaves, so a byte-wide page packs ~2x the resident tokens of a bf16
page), and each sequence owns a *block table* of physical page ids.

Three objects, all host-side and jax-free (device traffic is the engine's
job; everything here is plain ints and numpy rows):

* ``PageAllocator`` — free-list allocator with per-page refcounts. Page 0
  is reserved as a garbage page: unallocated block-table entries point at
  it, and retired slots' zombie writes land in it, so device code never
  needs an "is allocated" branch.
* ``RadixPrefixIndex`` — a radix tree over *page-granular token runs*
  (one edge per full page of ``page_size`` token ids) plus an optional
  partial tail per node, keyed additionally on the kv_spec string: two
  requests share a page only if their token prefixes AND cache formats
  match. The index holds its own refcount on every page it names, so
  prefixes survive the sequences that wrote them (system prompts stay
  resident across requests); an LRU sweep releases holdings under pool
  pressure.
* ``PagedKVManager`` — per-sequence block tables stitched over both:
  admission matches the index, borrows shared pages (incref), allocates
  owned pages for the rest, and emits copy-on-write instructions when the
  first written position lands inside a borrowed page. Sharing is safe
  without any device-side synchronization because writes are append-only:
  a sequence only ever writes positions >= its admission prefix, shared
  full pages are never written, and a shared partial tail is CoW-copied
  before the sharer's first write while readers only read the tail's
  valid prefix.

Why a page's content is shareable at all: K/V at position t is a pure
function of the token ids at positions <= t, the model weights, and the
static per-channel scales (which are per-model calibration constants —
the paged cache hoists them to one leaf per layer precisely so every page
is quantized under the same grid). Prefill fake-quantizes through the
cache grid and decode quantizes-on-write against the same static scales,
so the same token prefix always regenerates the same codes — the PR-3
resume invariant, now doing cross-request duty.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["PageAllocator", "RadixPrefixIndex", "PagedKVManager",
           "AdmitPlan", "GARBAGE_PAGE"]

# Physical page 0 is never allocated: it is the write sink for retired
# slots and the read target of unallocated block-table entries (reads of
# it are always masked by per-slot ``pos``).
GARBAGE_PAGE = 0


class PageAllocator:
    """Free-list page allocator with refcounts (page 0 reserved)."""

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError(f"need >= 2 pages (one is reserved), got {n_pages}")
        self.n_pages = int(n_pages)
        self._free: List[int] = list(range(n_pages - 1, 0, -1))  # pop() -> 1 first
        self._ref = np.zeros(n_pages, np.int64)

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_resident(self) -> int:
        """Allocated (live) pages, excluding the reserved garbage page."""
        return self.n_pages - 1 - len(self._free)

    def refcount(self, pid: int) -> int:
        return int(self._ref[pid])

    def alloc(self) -> Optional[int]:
        """One fresh page at refcount 1, or None when the pool is empty."""
        if not self._free:
            return None
        pid = self._free.pop()
        self._ref[pid] = 1
        return pid

    def incref(self, pid: int) -> None:
        if pid == GARBAGE_PAGE or self._ref[pid] <= 0:
            raise ValueError(f"incref on unallocated page {pid}")
        self._ref[pid] += 1

    def decref(self, pid: int) -> bool:
        """Drop one reference; returns True when the page was freed."""
        if pid == GARBAGE_PAGE or self._ref[pid] <= 0:
            raise ValueError(f"decref on unallocated page {pid} (double free?)")
        self._ref[pid] -= 1
        if self._ref[pid] == 0:
            self._free.append(pid)
            return True
        return False


@dataclasses.dataclass
class _Node:
    """One radix-tree node: the full page that ends this token run."""
    pid: int
    children: Dict[Tuple[int, ...], "_Node"] = dataclasses.field(
        default_factory=dict)
    # (token run shorter than a page, its page id): a sequence's last,
    # partially-filled page. Readers use only the run's length; a sharer
    # that extends it copies the page first (CoW in PagedKVManager).
    tail: Optional[Tuple[Tuple[int, ...], int]] = None
    last_used: int = 0


class RadixPrefixIndex:
    """Radix tree over page-granular token prefixes, refcount-holding.

    Keys are runs of ``page_size`` token ids (one edge per full page) with
    an optional sub-page tail per node; the whole index is additionally
    keyed on ``spec_key`` (the kv format string) — ``match`` with a
    different spec_key returns nothing, so a pool serving one format never
    hands codes to a consumer expecting another.
    """

    def __init__(self, alloc: PageAllocator, page_size: int, spec_key: str):
        self.alloc = alloc
        self.page_size = int(page_size)
        self.spec_key = str(spec_key)
        self._root = _Node(pid=-1)
        self._clock = 0
        self.n_holdings = 0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def match(self, tokens: Sequence[int], spec_key: str
              ) -> Tuple[List[int], int]:
        """Longest indexed prefix of ``tokens``: (page ids, token count).

        The returned pages cover ``count`` tokens: ``count // page_size``
        full pages plus, when ``count % page_size`` > 0, one final page of
        which only the first ``count % page_size`` positions are valid.
        No references are taken — the caller borrows via its allocator.
        """
        if str(spec_key) != self.spec_key:
            return [], 0
        toks = [int(t) for t in tokens]
        ps = self.page_size
        node, pids, used = self._root, [], 0
        while used + ps <= len(toks):
            key = tuple(toks[used:used + ps])
            child = node.children.get(key)
            if child is None:
                break
            node.last_used = child.last_used = self._tick()
            pids.append(child.pid)
            node = child
            used += ps
        if node.tail is not None:
            run, pid = node.tail
            rem = toks[used:]
            cp = 0
            for a, b in zip(run, rem):
                if a != b:
                    break
                cp += 1
            if cp > 0:
                node.last_used = self._tick()
                pids.append(pid)
                used += cp
        return pids, used

    def insert(self, tokens: Sequence[int], pids: Sequence[int],
               n_valid: int) -> int:
        """Index ``pids`` as the pages holding ``tokens[:n_valid]``.

        Full pages become radix nodes; a sub-page remainder becomes the
        end node's tail (replacing a shorter one). The index increfs every
        page for a *new* holding; existing nodes keep their original page
        (identical content by the determinism invariant — the caller's
        duplicate page simply stays caller-owned). Returns new holdings.
        """
        toks = [int(t) for t in tokens[:n_valid]]
        ps = self.page_size
        node, added, i = self._root, 0, 0
        while (i + 1) * ps <= len(toks):
            key = tuple(toks[i * ps:(i + 1) * ps])
            child = node.children.get(key)
            if child is None:
                child = _Node(pid=int(pids[i]), last_used=self._tick())
                self.alloc.incref(child.pid)
                node.children[key] = child
                self.n_holdings += 1
                added += 1
            node = child
            node.last_used = self._tick()
            i += 1
        rem = tuple(toks[i * ps:])
        if rem and i < len(pids):
            old = node.tail
            if old is None or len(old[0]) < len(rem):
                self.alloc.incref(int(pids[i]))
                node.tail = (rem, int(pids[i]))
                self.n_holdings += 1 - (0 if old is None else 1)
                if old is not None:
                    self.alloc.decref(old[1])
                added += 1
        return added

    def resident_tokens(self) -> int:
        """Distinct tokens resident in indexed pages (full pages count
        ``page_size``, tails their run length) — page-level dedup is
        inherent: a shared page appears once in the tree."""
        total = 0
        stack = [self._root]
        while stack:
            node = stack.pop()
            if node.pid >= 0:
                total += self.page_size
            if node.tail is not None:
                total += len(node.tail[0])
            stack.extend(node.children.values())
        return total

    def _droppable(self) -> List[Tuple[int, _Node, Optional[Tuple[int, ...]]]]:
        """(last_used, parent, child_key) for droppable holdings: every
        tail, and every childless (leaf) full-page node."""
        out = []
        stack = [self._root]
        while stack:
            node = stack.pop()
            if node.tail is not None:
                out.append((node.last_used, node, None))
            for key, child in node.children.items():
                if not child.children and child.tail is None:
                    out.append((child.last_used, node, key))
                else:
                    stack.append(child)
        out.sort(key=lambda t: t[0])
        return out

    def reclaim(self, n_pages: int) -> int:
        """LRU-drop holdings until >= ``n_pages`` pages were actually freed
        (a drop frees a page only when the index held its last reference).
        Returns the number freed; stops early when nothing is droppable."""
        freed = 0
        while freed < n_pages:
            cands = self._droppable()
            if not cands:
                break
            progressed = False
            for _, parent, key in cands:
                if key is None:
                    _, pid = parent.tail
                    parent.tail = None
                else:
                    pid = parent.children.pop(key).pid
                self.n_holdings -= 1
                progressed = True
                if self.alloc.decref(pid):
                    freed += 1
                if freed >= n_pages:
                    break
            if not progressed:
                break
        return freed


@dataclasses.dataclass(frozen=True)
class AdmitPlan:
    """Host-side admission result the engine executes on device.

    prefix_len  tokens of the context already resident in shared pages
                (prefill skips them; the suffix starts here)
    table       the slot's physical block-table row, garbage-page padded
    copies      (src_pid, dst_pid) pool copies to run BEFORE prefill —
                copy-on-write of a borrowed page the suffix will write into
    """
    prefix_len: int
    table: np.ndarray
    copies: Tuple[Tuple[int, int], ...]


@dataclasses.dataclass
class _Seq:
    pids: List[int]            # one held reference per entry
    length: int                # tokens covered by allocated pages


class PagedKVManager:
    """Block tables + prefix index over one page pool (one kv format).

    The engine drives it admit -> ensure* -> (register | suspend/release);
    every page reference the manager hands a sequence is returned through
    ``release``. ``check()`` recomputes refcounts from scratch — the
    invariant the property tests pin.
    """

    def __init__(self, n_pages: int, page_size: int, max_pages: int,
                 spec_key: str):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = int(page_size)
        self.max_pages = int(max_pages)
        self.alloc = PageAllocator(n_pages)
        self.index = RadixPrefixIndex(self.alloc, page_size, spec_key)
        self.spec_key = str(spec_key)
        self._seqs: Dict[int, _Seq] = {}
        # metrics surfaced via ServeEngine.stats()
        self.prefix_queries = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        self.pages_freed = 0
        self.pages_reclaimed = 0
        self.cow_copies = 0

    # -- allocation ----------------------------------------------------------

    def _alloc_one(self) -> int:
        pid = self.alloc.alloc()
        if pid is None:
            self.pages_reclaimed += self.index.reclaim(1)
            pid = self.alloc.alloc()
        if pid is None:
            raise RuntimeError(
                "KV page pool exhausted: every page is referenced by a "
                "running sequence (raise n_pages or lower n_slots/max_len)")
        return pid

    def _pages_for(self, n_tokens: int) -> int:
        return -(-int(n_tokens) // self.page_size)

    def _row(self, pids: List[int]) -> np.ndarray:
        row = np.full(self.max_pages, GARBAGE_PAGE, np.int32)
        row[:len(pids)] = pids
        return row

    # -- sequence lifecycle --------------------------------------------------

    def admit(self, rid: int, tokens: Sequence[int], alloc_len: int,
              page_align: bool = False) -> AdmitPlan:
        """Plan admission of ``tokens`` with pages covering ``alloc_len``.

        Matches the prefix index (capped at ``len(tokens) - 1`` so at
        least one token prefills and yields logits to sample from),
        borrows the matched pages, copy-on-writes the one borrowed page
        the suffix will write into (iff the prefix ends mid-page), and
        allocates owned pages for the rest of ``alloc_len`` (the
        bucket-padded context; junk beyond the true length is masked by
        ``pos`` exactly as in the dense engine).

        ``page_align`` rounds the hit DOWN to a page boundary: fewer
        tokens skipped (up to page_size - 1 re-prefill, stream-identical
        by code determinism) but no mid-page suffix starts — the engine
        sets it alongside prompt bucketing, whose point is bounding
        prefill compile variants, which token-granular ``prefix_len``
        (a static jit argument) would otherwise undo.
        """
        if rid in self._seqs:
            raise ValueError(f"sequence {rid} already admitted")
        ps = self.page_size
        n_total = self._pages_for(max(alloc_len, len(tokens)))
        if n_total > self.max_pages:
            raise ValueError(
                f"context of {alloc_len} tokens needs {n_total} pages > "
                f"max_pages {self.max_pages}")
        matched, hit = self.index.match(tokens, self.spec_key)
        self.prefix_queries += 1
        prefix_len = min(hit, len(tokens) - 1)
        if page_align:
            prefix_len -= prefix_len % ps
        n_full = prefix_len // ps
        pids: List[int] = []
        copies: List[Tuple[int, int]] = []
        try:
            for pid in matched[:n_full]:
                self.alloc.incref(pid)       # borrowed, never written
                pids.append(pid)
            if prefix_len % ps:
                # the suffix's first write lands inside this borrowed
                # page: copy it into an owned page before anyone writes
                src = matched[n_full]
                dst = self._alloc_one()
                copies.append((src, dst))
                self.cow_copies += 1
                pids.append(dst)
            while len(pids) < n_total:
                pids.append(self._alloc_one())
        except RuntimeError:
            # roll back partial admission state: a failed admit must not
            # leak references (check() would flag the drift)
            for pid in pids:
                self.alloc.decref(pid)
            raise
        if prefix_len > 0:
            self.prefix_hits += 1
            self.prefix_hit_tokens += prefix_len
        self._seqs[rid] = _Seq(pids=pids, length=n_total * ps)
        return AdmitPlan(prefix_len=prefix_len, table=self._row(pids),
                         copies=tuple(copies))

    def ensure(self, rid: int, n_tokens: int) -> Optional[np.ndarray]:
        """Grow ``rid``'s table to cover ``n_tokens``; returns the new row
        when pages were added, None when coverage was already sufficient."""
        seq = self._seqs[rid]
        need = self._pages_for(n_tokens)
        if need > self.max_pages:
            raise ValueError(
                f"coverage of {n_tokens} tokens needs {need} pages > "
                f"max_pages {self.max_pages}")
        if need <= len(seq.pids):
            return None
        while len(seq.pids) < need:
            seq.pids.append(self._alloc_one())
        seq.length = len(seq.pids) * self.page_size
        return self._row(seq.pids)

    def register(self, rid: int, tokens: Sequence[int], n_valid: int) -> int:
        """Index ``rid``'s pages as holding ``tokens[:n_valid]`` so later
        requests (and this request's own resume) can share them."""
        seq = self._seqs[rid]
        n_use = self._pages_for(n_valid)
        return self.index.insert(tokens, seq.pids[:n_use], n_valid)

    def release(self, rid: int) -> int:
        """Return every page reference ``rid`` holds; returns pages freed
        (pages the index also names survive for future prefix hits)."""
        seq = self._seqs.pop(rid)
        freed = sum(1 for pid in seq.pids if self.alloc.decref(pid))
        self.pages_freed += freed
        return freed

    def suspend(self, rid: int, tokens: Sequence[int], n_valid: int) -> int:
        """Evict: index the sequence's pages (full pages AND the partial
        tail), then drop its own references. Resume is a normal ``admit``
        whose prefix match re-attaches everything that survived — the
        "no re-prefill on resume" path."""
        self.register(rid, tokens, n_valid)
        return self.release(rid)

    # -- introspection -------------------------------------------------------

    def seq_pages(self, rid: int) -> List[int]:
        return list(self._seqs[rid].pids)

    def stats(self) -> Dict[str, float]:
        return {
            "resident_pages": self.alloc.n_resident,
            "free_pages": self.alloc.n_free,
            "prefix_queries": self.prefix_queries,
            "prefix_hits": self.prefix_hits,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefix_hit_rate": (self.prefix_hits / self.prefix_queries
                                if self.prefix_queries else 0.0),
            "pages_freed": self.pages_freed,
            "pages_reclaimed": self.pages_reclaimed,
            "cow_copies": self.cow_copies,
            "index_holdings": self.index.n_holdings,
            "index_resident_tokens": self.index.resident_tokens(),
        }

    def check(self) -> None:
        """Recompute refcounts from scratch; raises on any drift — the
        no-double-free / no-leak invariant the property suite pins."""
        expect = np.zeros(self.alloc.n_pages, np.int64)
        for seq in self._seqs.values():
            for pid in seq.pids:
                expect[pid] += 1
        stack = [self.index._root]
        while stack:
            node = stack.pop()
            if node.pid >= 0:
                expect[node.pid] += 1
            if node.tail is not None:
                expect[node.tail[1]] += 1
            stack.extend(node.children.values())
        if not np.array_equal(expect, self.alloc._ref):
            bad = np.nonzero(expect != self.alloc._ref)[0]
            raise AssertionError(
                f"refcount drift on pages {bad.tolist()}: held "
                f"{self.alloc._ref[bad].tolist()} vs reachable "
                f"{expect[bad].tolist()}")
        free = set(self.alloc._free)
        if len(free) != len(self.alloc._free):
            raise AssertionError("free list contains duplicates")
        live = set(np.nonzero(self.alloc._ref > 0)[0].tolist())
        if free & live:
            raise AssertionError(f"pages both free and referenced: {free & live}")

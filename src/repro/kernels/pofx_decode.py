"""Pallas TPU kernel: PoFx decode — normalized posit codes -> FxP int8.

The TPU port of the paper's PoFx converter (Algorithm 1). On FPGA the
converter is an LZD + barrel shifter; on TPU the same stages become lane-wise
int32 bit operations on the VPU — every lane decodes one weight, no
data-dependent control flow. HBM holds uint8 codes ((N-1) <= 8 bits each),
VMEM tiles are decoded in place; the output int8 feeds the MXU (or is widened
to bf16 by the fused kernel).

BlockSpec: 2D (block_r, block_c) tiles in VMEM. uint8 tiles are (32, 128)
packed on TPU; we keep block shapes multiples of (32, 128) for lane/sublane
alignment with int8/uint8 layouts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import decode_norm_to_fxp

__all__ = ["pofx_decode"]

DEFAULT_BLOCK = (256, 512)


def _decode_kernel(codes_ref, out_ref, *, N: int, ES: int, M: int):
    codes = codes_ref[...].astype(jnp.int32)
    out_ref[...] = decode_norm_to_fxp(codes, N, ES, M).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("N", "ES", "M", "block", "interpret"))
def pofx_decode(codes: jax.Array, N: int, ES: int, M: int = 8,
                block=DEFAULT_BLOCK, interpret: bool | None = None) -> jax.Array:
    """Decode a 2D array of normalized posit codes to FxP int8 codes."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    r, c = codes.shape
    br, bc = min(block[0], r), min(block[1], c)
    pr, pc = (-r) % br, (-c) % bc
    padded = jnp.pad(codes, ((0, pr), (0, pc)))
    grid = (padded.shape[0] // br, padded.shape[1] // bc)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, N=N, ES=ES, M=M),
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(padded.shape, jnp.int8),
        interpret=interpret,
    )(padded)
    return out[:r, :c]

"""Tables 3/4: Pareto analysis of MAC/quantizer design points.

Objectives (all minimized): avg weight quantization error, bits/weight
(storage+communication), decode cost (op count — the PDP/LUT analogue).
Reports front membership per category and the paper's headline: hypervolume
gain from adding PoFx-based points over {Posit, FxP} alone.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import fxp as fxp_mod
from repro.core.pareto import hypervolume_gain, pareto_mask
from repro.core.pofx import pofx_normalized
from repro.core.posit import posit_decode
from repro.core.quantizers import QuantSpec, quantize, storage_bits

from .common import avg_abs_rel_error, jaxpr_ops, vgg_like_weights, write_csv


def _points(smoke: bool = False):
    """Each point: (category, name, avg_err, bits/weight, MAC cost).

    MAC-cost model follows the paper's Fig 14/15 structure: the posit-only
    MAC decodes AND re-normalizes per operation (decode+encode datapath on
    both operands), the PoFx MAC decodes the stored weight once per use and
    then runs integer multiply-add, the FxP MAC is integer-only.
    """
    import dataclasses
    w = vgg_like_weights(1 << 12 if smoke else 1 << 16)
    codes = jnp.asarray(np.arange(1 << 8 if smoke else 1 << 12) % 16,
                        jnp.int32)
    int_mac = 2  # mul + add

    def q(spec):
        spec = dataclasses.replace(spec, scale_mode="tensor_pow2")
        qt = quantize(jnp.asarray(w, jnp.float32), spec)
        return (avg_abs_rel_error(w, np.asarray(qt.dequantize(jnp.float32))),
                storage_bits(qt) / w.size)

    pts = []
    for M in (7, 8, 16):
        err, bits = q(QuantSpec(kind="fxp", M=M, F=M - 1))
        pts.append(("fxp", f"fxp{M}", err, bits, int_mac))
    for N in (5, 6, 7, 8):
        for ES in (0, 1, 2):
            err, bits = q(QuantSpec(kind="posit", N=N, ES=ES))
            dec = jaxpr_ops(lambda c, N=N, ES=ES: posit_decode(c, N, ES),
                            codes)
            # decode both operands + renormalize/encode the result (~decode)
            pts.append(("posit", f"posit({N},{ES})", err, bits,
                        3 * dec + int_mac))
    for N in (6, 7, 8):
        for ES in (1, 2):
            err, bits = q(QuantSpec(kind="pofx", N=N, ES=ES, M=8))
            dec = jaxpr_ops(lambda c, N=N, ES=ES:
                            pofx_normalized(c, N, ES, 8)[0], codes)
            pts.append(("pofx", f"pofx({N - 1},{ES})", err, bits,
                        dec + int_mac))
    return pts


def run(smoke: bool = False):
    pts = _points(smoke)
    obj = np.array([[p[2], p[3], p[4]] for p in pts])
    mask = pareto_mask(obj)
    rows = [{"category": p[0], "scheme": p[1], "avg_rel": p[2],
             "bits_per_weight": p[3], "decode_ops": p[4],
             "on_front": bool(m)} for p, m in zip(pts, mask)]
    write_csv("table3_pareto", rows)
    front_count = {}
    for r in rows:
        if r["on_front"]:
            front_count[r["category"]] = front_count.get(r["category"], 0) + 1
    base = obj[[i for i, p in enumerate(pts) if p[0] != "pofx"]]
    extra = obj[[i for i, p in enumerate(pts) if p[0] == "pofx"]]
    ref = obj.max(axis=0) * 1.1 + 1e-9
    gain = hypervolume_gain(base, extra, ref)
    return rows, {"front_counts": front_count,
                  "hypervolume_gain_pct_from_pofx": gain,
                  "claim_pofx_expands_front": gain > 0}

"""chameleon-34b [vlm]: 48L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=65536 — early-fusion VQ image tokens (image tokens live in the vocab;
the VQ tokenizer is the assignment's stub), qk-norm [arXiv:2405.09818]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b", family="dense",
    n_layers=48, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=22016, vocab_size=65536, act="silu", qk_norm=True,
    rope_theta=10000.0,
)

from .adamw import (OptConfig, apply_updates, global_norm, init_opt_state,
                    lr_schedule)

__all__ = ["OptConfig", "init_opt_state", "apply_updates", "lr_schedule",
           "global_norm"]

"""Logical-axis sharding: one rules table, divisibility-aware fallbacks.

Every tensor in the model is annotated with *logical* axis names; a
``ShardingCtx`` maps them to mesh axes (GSPMD PartitionSpec) with automatic
fallback to replication when a dimension is not divisible by its mesh axis.

Key mappings (production mesh (pod, data, model)):

  batch      -> (pod, data)      DP across pods and the data axis
  p_embed    -> data             FSDP: params sharded over data, all-gathered
                                 per layer inside the scan
  heads/kv_heads/mlp/experts/vocab -> model   (tensor/expert parallel)
  head_dim_tp -> model           fallback TP for archs whose head counts
                                 don't divide the model axis (llama4's 40H):
                                 contracting-dim sharding; GSPMD turns the
                                 score/attend einsums into psum partials
  kv_seq     -> model            sequence-sharded KV cache for decode —
                                 GSPMD partitions the softmax reductions into
                                 the flash-decoding pattern (cheap all-reduce
                                 of per-chip max/sum stats instead of
                                 gathering a 500k-token cache)

CPU smoke tests run with mesh=None: same code, no constraints.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingCtx", "make_ctx"]

Logical = Union[str, None]


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    mesh: Optional[Mesh]
    rules: "dict[str, Tuple[str, ...]]"

    def axis_size(self, mesh_axis: str) -> int:
        if self.mesh is None or mesh_axis not in self.mesh.shape:
            return 1
        return self.mesh.shape[mesh_axis]

    def spec(self, logical: Tuple[Logical, ...], shape: Tuple[int, ...]) -> P:
        """PartitionSpec for ``shape`` with divisibility + reuse fallbacks."""
        if self.mesh is None:
            return P()
        used: set = set()
        out = []
        for name, dim in zip(logical, shape):
            axes = self.rules.get(name) if name else None
            if not axes:
                out.append(None)
                continue
            picked = []
            prod = 1
            for ax in axes:
                if ax in used or ax not in self.mesh.shape:
                    continue
                prod *= self.mesh.shape[ax]
                picked.append(ax)
            if not picked or dim % prod != 0:
                out.append(None)
                continue
            used.update(picked)
            out.append(tuple(picked) if len(picked) > 1 else picked[0])
        return P(*out)

    def constrain(self, x: jax.Array, *logical: Logical) -> jax.Array:
        """with_sharding_constraint by logical names (no-op without a mesh)."""
        if self.mesh is None:
            return x
        assert len(logical) == x.ndim, (logical, x.shape)
        spec = self.spec(tuple(logical), x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def sharding(self, logical: Tuple[Logical, ...], shape: Tuple[int, ...]):
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(tuple(logical), shape))


def make_ctx(mesh: Optional[Mesh], *, fsdp: bool = True,
             sequence_parallel: bool = False) -> ShardingCtx:
    """Build the rules table for whatever mesh we were given.

    sequence_parallel shards the *residual stream* (block inputs/outputs,
    norms, checkpointed activations) over the model axis along seq —
    Megatron-SP. Attention/MLP interiors stay head/mlp-sharded via the
    "seq_attn" alias; GSPMD inserts the all-gather/reduce-scatter pair at
    the block boundary. This is what lets 100B+ dense training fit HBM
    (the per-layer activation checkpoint shrinks by the model-axis size).
    """
    if mesh is None:
        return ShardingCtx(None, {})
    names = set(mesh.axis_names)
    batch = tuple(a for a in ("pod", "data") if a in names)
    data = ("data",) if "data" in names else ()
    model = ("model",) if "model" in names else ()
    rules = {
        # activations
        "batch": batch,
        "seq": model if sequence_parallel else (),
        "seq_attn": (),             # seq inside attention/MLP (gathered)
        "embed": (),
        "heads": model,
        "kv_heads": model,
        "head_dim": (),
        "head_dim_tp": model,       # fallback TP (contracting-dim)
        "mlp": model,
        "experts": model,
        "vocab": model,
        "kv_seq": model,            # sequence-sharded decode cache
        "expert_cap": (),
        # SSM: channel (d_inner) dims shard over model — in_proj columns,
        # out_proj rows (contraction -> psum), per-channel scan state.
        # §Perf iter A: these names previously had NO rule, which silently
        # replicated every mamba layer 16x over the model axis.
        "d_inner": model,
        "d_inner2": model,
        "d_inner_r": model,
        "heads_r": model,
        # params
        "p_embed": data if fsdp else (),
        "p_unsharded": (),
        "layers": (),
        "state": (),
        "conv": (),
    }
    return ShardingCtx(mesh, rules)

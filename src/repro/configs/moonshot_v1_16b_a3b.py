"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (GQA kv=16) expert
d_ff=1408 vocab=163840, MoE 64e top-6 + 2 shared experts (moonlight /
deepseek-v3 style) [hf:moonshotai/Moonlight-16B-A3B]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_head=128,
    d_ff=1408, vocab_size=163840, act="silu",
    n_experts=64, top_k=6, moe_every=1, n_shared_experts=2,
    rope_theta=50000.0,
)

"""Pallas kernels vs pure-jnp oracles: allclose sweep + throughput.

Kernels run in interpret mode on this CPU container (the TPU lowering is
exercised by BlockSpec construction either way); correctness is the
contract, timing is recorded for completeness.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.fxp_matmul import fxp_matmul
from repro.kernels.pofx_decode import pofx_decode
from repro.kernels.pofx_matmul import pofx_matmul
from repro.kernels.ref import fxp_matmul_ref, pofx_decode_ref, pofx_matmul_ref

from .common import wall_time, write_csv


def run(smoke: bool = False):
    rng = np.random.default_rng(0)
    rows = []
    # decode kernel sweep (smoke keeps one ragged + one aligned shape —
    # the tail-tile masking is the path that rots)
    dec_shapes = ((128, 256), (257, 130)) if smoke \
        else ((128, 256), (257, 130), (512, 512))
    for (r, c) in dec_shapes:
        for N, ES in (((8, 2),) if smoke else ((8, 2), (6, 1))):
            codes = jnp.asarray(rng.integers(0, 1 << (N - 1), (r, c)),
                                jnp.int32)
            out = pofx_decode(codes, N, ES, 8, block=(128, 128), interpret=True)
            ref = pofx_decode_ref(codes, N, ES, 8)
            ok = bool(jnp.all(out == ref))
            rows.append({"kernel": "pofx_decode", "shape": f"{r}x{c}",
                         "cfg": f"({N},{ES})", "exact": ok,
                         "us": wall_time(lambda: pofx_decode(
                             codes, N, ES, 8, block=(128, 128),
                             interpret=True), reps=2) * 1e6})
            assert ok
    # fused matmul sweep
    for (m, k, n) in (((64, 128, 96),) if smoke
                      else ((64, 128, 96), (130, 257, 66))):
        x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
        codes = jnp.asarray(rng.integers(0, 128, (k, n)), jnp.int32)
        scale = jnp.asarray(rng.uniform(0.5, 2.0, (n,)), jnp.float32)
        for mode in ("bitlevel", "onehot"):
            got = pofx_matmul(x, codes, scale, 8, 2, 8, blocks=(64, 64, 64),
                              decode_mode=mode, interpret=True)
            ref = pofx_matmul_ref(x, codes, scale, 8, 2, 8)
            err = float(jnp.max(jnp.abs(got - ref)))
            rows.append({"kernel": f"pofx_matmul[{mode}]",
                         "shape": f"{m}x{k}x{n}", "cfg": "(8,2)",
                         "exact": err < 1e-3, "us": err})
            assert err < 1e-3, (mode, err)
    # int8 MAC
    a = jnp.asarray(rng.integers(-127, 127, (96, 160)), jnp.int8)
    b = jnp.asarray(rng.integers(-127, 127, (160, 64)), jnp.int8)
    got = fxp_matmul(a, b, blocks=(64, 64, 64), interpret=True)
    ok = bool(jnp.all(got == fxp_matmul_ref(a, b)))
    rows.append({"kernel": "fxp_matmul", "shape": "96x160x64", "cfg": "int8",
                 "exact": ok, "us": 0.0})
    assert ok
    write_csv("kernels", rows)
    return rows, {"all_exact": all(r["exact"] for r in rows)}

"""Serving example: continuous-batching generation with PoFx-stored weights.

Wraps repro.launch.serve: initializes a model, quantizes the weights to the
paper's normalized-posit format, and serves a staggered stream of requests
through the slot-based engine (admission, scan-fused decode, per-slot
stopping), reporting storage + throughput. ``--use-kernel`` routes the
quantized matmuls through the fused Pallas PoFx kernel (interpret on CPU).

    PYTHONPATH=src python examples/serve_quantized.py --arch moonshot-v1-16b-a3b
    PYTHONPATH=src python examples/serve_quantized.py --use-kernel --temperature 0.8
"""
import argparse

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--quant", default="pofx8")
    ap.add_argument("--use-kernel", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    argv = ["--arch", args.arch, "--smoke", "--quant", args.quant,
            "--batch", "4", "--prompt-len", "48", "--gen", "16",
            "--arrival-gap", "4", "--temperature", str(args.temperature)]
    if args.use_kernel:
        argv.append("--use-kernel")
    serve_main(argv)

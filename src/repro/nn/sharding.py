"""Logical-axis sharding: one rules table, divisibility-aware fallbacks.

Every tensor in the model is annotated with *logical* axis names; a
``ShardingCtx`` maps them to mesh axes (GSPMD PartitionSpec) with automatic
fallback to replication when a dimension is not divisible by its mesh axis.

Key mappings (production mesh (pod, data, model)):

  batch      -> (pod, data)      DP across pods and the data axis
  p_embed    -> data             FSDP: params sharded over data, all-gathered
                                 per layer inside the scan
  heads/kv_heads/mlp/experts/vocab -> model   (tensor/expert parallel)
  head_dim_tp -> model           fallback TP for archs whose head counts
                                 don't divide the model axis (llama4's 40H):
                                 contracting-dim sharding; GSPMD turns the
                                 score/attend einsums into psum partials
  kv_seq     -> model            sequence-sharded KV cache for decode —
                                 GSPMD partitions the softmax reductions into
                                 the flash-decoding pattern (cheap all-reduce
                                 of per-chip max/sum stats instead of
                                 gathering a 500k-token cache)

Serving tensor parallelism (DESIGN.md §9) uses a second, 1-D mesh shape:
``("tp",)`` (``launch.mesh.make_tp_mesh``). Its rules table shards the
Megatron axes only — attention heads, MLP hidden, experts, and the decode
KV cache's head axis — and the serving engine runs the model *manually*
inside ``shard_map`` with a mesh-less ctx whose ``tp_axis`` is set:
``constrain`` no-ops and ``psum`` becomes the single cross-device
reduction each block issues after its row-sharded projection.

CPU smoke tests run with mesh=None: same code, no constraints.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingCtx", "make_ctx", "manual_tp_ctx", "shard_map_compat",
           "shard_policy_params", "logical_specs", "TP_AXIS"]

Logical = Union[str, None]

TP_AXIS = "tp"


@dataclasses.dataclass(frozen=True)
class ShardingCtx:
    mesh: Optional[Mesh]
    rules: "dict[str, Tuple[str, ...]]"
    # Set when model code runs *inside* a shard_map over a serving TP mesh:
    # every mesh axis is manual there, so GSPMD constraints are meaningless
    # (mesh is None) and collectives are explicit — ``psum`` is the one each
    # block calls after its row-sharded matmul.
    tp_axis: Optional[str] = None

    def axis_size(self, mesh_axis: str) -> int:
        if self.mesh is None or mesh_axis not in self.mesh.shape:
            return 1
        return self.mesh.shape[mesh_axis]

    def psum(self, x: jax.Array) -> jax.Array:
        """Sum partial results over the manual TP axis (no-op outside one).

        Correctness contract: callers invoke this exactly where a
        contraction dim was sharded by ``shard_policy_params`` (attention
        wo, MLP down-proj, the MoE expert combine) — the rules table and
        the divisibility *errors* (not fallbacks) in shard_policy_params
        guarantee those dims really are sharded whenever tp_axis is set.
        """
        if self.tp_axis is None:
            return x
        return jax.lax.psum(x, self.tp_axis)

    def spec(self, logical: Tuple[Logical, ...], shape: Tuple[int, ...]) -> P:
        """PartitionSpec for ``shape`` with divisibility + reuse fallbacks."""
        if self.mesh is None:
            return P()
        used: set = set()
        out = []
        for name, dim in zip(logical, shape):
            axes = self.rules.get(name) if name else None
            if not axes:
                out.append(None)
                continue
            picked = []
            prod = 1
            for ax in axes:
                if ax in used or ax not in self.mesh.shape:
                    continue
                prod *= self.mesh.shape[ax]
                picked.append(ax)
            if not picked or dim % prod != 0:
                out.append(None)
                continue
            used.update(picked)
            out.append(tuple(picked) if len(picked) > 1 else picked[0])
        return P(*out)

    def constrain(self, x: jax.Array, *logical: Logical) -> jax.Array:
        """with_sharding_constraint by logical names (no-op without a mesh)."""
        if self.mesh is None:
            return x
        assert len(logical) == x.ndim, (logical, x.shape)
        spec = self.spec(tuple(logical), x.shape)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def sharding(self, logical: Tuple[Logical, ...], shape: Tuple[int, ...]):
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(tuple(logical), shape))


def make_ctx(mesh: Optional[Mesh], *, fsdp: bool = True,
             sequence_parallel: bool = False) -> ShardingCtx:
    """Build the rules table for whatever mesh we were given.

    sequence_parallel shards the *residual stream* (block inputs/outputs,
    norms, checkpointed activations) over the model axis along seq —
    Megatron-SP. Attention/MLP interiors stay head/mlp-sharded via the
    "seq_attn" alias; GSPMD inserts the all-gather/reduce-scatter pair at
    the block boundary. This is what lets 100B+ dense training fit HBM
    (the per-layer activation checkpoint shrinks by the model-axis size).
    """
    if mesh is None:
        return ShardingCtx(None, {})
    names = set(mesh.axis_names)
    if TP_AXIS in names:
        return ShardingCtx(mesh, _tp_rules())
    batch = tuple(a for a in ("pod", "data") if a in names)
    data = ("data",) if "data" in names else ()
    model = ("model",) if "model" in names else ()
    rules = {
        # activations
        "batch": batch,
        "seq": model if sequence_parallel else (),
        "seq_attn": (),             # seq inside attention/MLP (gathered)
        "embed": (),
        "heads": model,
        "kv_heads": model,
        "head_dim": (),
        "head_dim_tp": model,       # fallback TP (contracting-dim)
        "mlp": model,
        "experts": model,
        "vocab": model,
        "kv_seq": model,            # sequence-sharded decode cache
        "kv_heads_c": (),           # decode-cache head axis (TP mesh only)
        "expert_cap": (),
        # SSM: channel (d_inner) dims shard over model — in_proj columns,
        # out_proj rows (contraction -> psum), per-channel scan state.
        # §Perf iter A: these names previously had NO rule, which silently
        # replicated every mamba layer 16x over the model axis.
        "d_inner": model,
        "d_inner2": model,
        "d_inner_r": model,
        "heads_r": model,
        # params
        "p_embed": data if fsdp else (),
        "p_unsharded": (),
        "layers": (),
        "state": (),
        "conv": (),
    }
    return ShardingCtx(mesh, rules)


# ---------------------------------------------------------------------------
# Serving tensor parallelism over a 1-D ("tp",) mesh (DESIGN.md §9)
# ---------------------------------------------------------------------------


def _tp_rules() -> dict:
    """Megatron-style serving TP: shard ONLY the axes whose row-sharded
    contraction has an explicit ``ctx.psum`` in the model code — attention
    heads (wq/wk/wv columns, wo rows via "mlp"), MLP hidden, experts — plus
    the decode KV cache's head axis. Everything else (embed/unembed, norms,
    router, SSM channel dims, activations) replicates: SSM blocks run
    replicated rather than splitting mamba's packed in_proj output, and the
    residual stream never shards, so slot logic stays device-count-agnostic.
    """
    tp = (TP_AXIS,)
    return {
        "heads": tp,
        "kv_heads": tp,
        "mlp": tp,                  # MLP hidden AND attention wo's row dim
        "experts": tp,
        "kv_heads_c": tp,           # decode-cache (B, G, S, Dh) head axis
    }


def manual_tp_ctx(axis: str = TP_AXIS) -> ShardingCtx:
    """Ctx for model code running inside a shard_map over the TP mesh:
    no mesh (constrain no-ops; every axis is manual), explicit psum."""
    return ShardingCtx(None, {}, tp_axis=axis)


def shard_map_compat(f, mesh, in_specs, out_specs, manual_axes=None):
    """jax.shard_map across jax versions — the ONE shim both users share
    (the TP serving engine and the posit8-compressed train step): new API
    (axis_names/check_vma) when available, else jax.experimental.shard_map
    (auto/check_rep=False — pallas calls inside carry no replication rule).

    ``manual_axes`` defaults to every mesh axis (the serving-TP case);
    pass a subset for partial-manual (train's pod-only grad transport).
    """
    manual = set(manual_axes if manual_axes is not None else mesh.axis_names)
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=manual,
                             check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    auto = frozenset(a for a in mesh.axis_names if a not in manual)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               auto=auto, check_rep=False)


def logical_specs(ctx: ShardingCtx, logical: Any, abstract: Any) -> Any:
    """PartitionSpec tree for a plain (non-quantized) pytree zipped against
    a logical-axis tree (leaves = tuples of axis names). Indivisible sharded
    dims raise (strict, like shard_policy_params): used for the TP decode
    cache, where a silently replicated head axis would desynchronize the
    per-device attention shards.
    """
    def one(ax, leaf):
        ax = tuple(ax)[:leaf.ndim]
        ax = ax + (None,) * (leaf.ndim - len(ax))
        return _strict_spec(ctx, ax, leaf.shape, "/".join(map(str, ax)))

    return jax.tree.map(one, logical, abstract,
                        is_leaf=lambda x: isinstance(x, tuple))


def shard_policy_params(params: Any, logical: Any, ctx: ShardingCtx) -> Any:
    """PartitionSpec tree for a (possibly policy-quantized) parameter tree.

    Plain leaves get the spec their logical axes name. ``QuantizedTensor``
    leaves get a QuantizedTensor-shaped spec node: codes take the logical
    spec; the scale leaf shards *with* its codes — same mesh axis on every
    dim where the scale varies (size == codes dim), replicated where it
    broadcasts (size 1). Sharding a quantized leaf is only valid when the
    per-channel scale layout is congruent with the sharded axis
    (``core.policy.validate_scale_sharding``) and the dim divides the mesh
    axis; both violations RAISE — a silent replication fallback would break
    the manual-psum contract (``ShardingCtx.psum``).
    """
    from repro.core.policy import validate_scale_sharding
    from repro.core.quantizers import QuantizedTensor

    is_qt = lambda x: isinstance(x, QuantizedTensor)
    flat = jax.tree_util.tree_flatten_with_path(params, is_leaf=is_qt)[0]
    treedef = jax.tree_util.tree_structure(params, is_leaf=is_qt)
    log_flat = jax.tree_util.tree_flatten(
        logical, is_leaf=lambda x: isinstance(x, tuple))[0]
    if len(flat) != len(log_flat):
        raise ValueError(
            f"params tree has {len(flat)} leaves but the logical tree names "
            f"{len(log_flat)}")
    out = []
    for (path, leaf), ax in zip(flat, log_flat):
        name = "/".join(str(getattr(k, "key", getattr(k, "name", k)))
                        for k in path)
        shape = leaf.shape
        ax = tuple(ax) + (None,) * (len(shape) - len(ax))
        spec = _strict_spec(ctx, ax, shape, name)
        if not is_qt(leaf):
            out.append(spec)
            continue
        scale_spec = validate_scale_sharding(
            name, leaf.codes.shape, leaf.scale.shape, spec)
        out.append(QuantizedTensor(spec, scale_spec, leaf.spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def _strict_spec(ctx: ShardingCtx, logical, shape, name: str) -> P:
    """Like ``ShardingCtx.spec`` but indivisibility is an error, not a
    replication fallback: manual-mode psum correctness depends on the
    named dims actually being sharded."""
    if ctx.mesh is None:
        return P()
    out = []
    for axname, dim in zip(logical, shape):
        axes = ctx.rules.get(axname) if axname else None
        axes = tuple(a for a in (axes or ()) if a in ctx.mesh.shape)
        if not axes:
            out.append(None)
            continue
        prod = 1
        for a in axes:
            prod *= ctx.mesh.shape[a]
        if dim % prod != 0:
            raise ValueError(
                f"cannot tensor-parallel {name!r}: the {'x'.join(axes)} "
                f"mesh axis ({prod} devices) does not divide dim "
                f"{axname!r} of size {dim}; pick a tp that divides it")
        out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract the roofline inputs from the compiled
artifact. (The XLA_FLAGS line above MUST run before any jax import — jax
locks the device count on first init.)

Per cell this driver:
  1. builds the model + abstract state (ShapeDtypeStruct, no allocation),
  2. jits the right step (train_step / prefill / decode_step) with explicit
     in/out shardings and donation,
  3. ``.lower().compile()`` on the (16,16) single-pod or (2,16,16)
     multi-pod mesh — success IS the deliverable,
  4. prints ``compiled.memory_analysis()`` / ``cost_analysis()`` and parses
     collective bytes from the post-SPMD HLO (hlo_analysis.py),
  5. writes a JSON record under experiments/dryrun/ for §Roofline.

Usage:
    python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh both] [--force]

``--all`` runs each cell in a fresh subprocess (compile-memory isolation;
a crash or OOM in one cell cannot take down the sweep) and skips cells
whose JSON already exists.
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from typing import Any, Dict, Optional

__all__ = ["run_cell", "main"]

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# Per-arch training knobs chosen by napkin math over v5e HBM (16 GB/chip):
# microbatches bound the per-layer activation checkpoints; SP shards the
# residual stream over the model axis; posit8 moments (the paper's codec on
# optimizer state) halve the Adam footprint for the 100B+ models.
TRAIN_KNOBS: Dict[str, Dict[str, Any]] = {
    "llama3-405b":              dict(microbatch=8, sequence_parallel=True, opt="posit8"),
    "nemotron-4-340b":          dict(microbatch=8, sequence_parallel=True, opt="posit8"),
    "deepseek-67b":             dict(microbatch=4, sequence_parallel=True, opt="none"),
    "chameleon-34b":            dict(microbatch=2, sequence_parallel=True, opt="none"),
    "yi-9b":                    dict(microbatch=1, sequence_parallel=True, opt="none"),
    "llama4-maverick-400b-a17b": dict(microbatch=2, sequence_parallel=True, opt="posit8"),
    "moonshot-v1-16b-a3b":      dict(microbatch=1, sequence_parallel=True, opt="none"),
    "chameleon-7b":             dict(microbatch=1, sequence_parallel=True, opt="none"),
    "falcon-mamba-7b":          dict(microbatch=1, sequence_parallel=False, opt="none"),
    "whisper-medium":           dict(microbatch=1, sequence_parallel=True, opt="none"),
    "zamba2-1.2b":              dict(microbatch=1, sequence_parallel=False, opt="none"),
}


def _cell_path(out_dir: str, arch: str, shape: str, mesh_kind: str,
               quant: str) -> str:
    import re
    safe = re.sub(r"[^A-Za-z0-9._-]+", "-", quant)  # policy strings have */=,
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}__{safe}.json")


def analytic_model_flops(cfg, shape) -> float:
    """The spec's MODEL_FLOPS convention: 6*N*D train, 2*N*D forward-only."""
    n = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return float(mult) * n * tokens


def _trip_counts(model, cfg, rcfg, shape) -> list:
    if cfg.family == "moe":
        L = cfg.n_layers // cfg.moe_every
    else:
        L = cfg.n_layers
    trips = []
    if shape.kind == "train" and rcfg.microbatch > 1:
        trips.append(rcfg.microbatch)
    trips.append(L)
    if shape.kind in ("train", "prefill"):
        if cfg.family in ("ssm", "hybrid"):
            inner = max(shape.seq_len // cfg.ssm_chunk, 1)
        else:
            inner = max(shape.seq_len // rcfg.attn_kv_chunk, 1)
        trips.append(inner)
    return trips


def run_cell(arch: str, shape_name: str, mesh_kind: str = "single",
             quant: str = "auto", out_dir: str = OUT_DIR,
             verbose: bool = True) -> Dict[str, Any]:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import ARCHS, LONG_CONTEXT_ARCHS, RunConfig
    from repro.configs.base import SHAPES
    from repro.core.policy import QuantPolicy
    from repro.core.quantizers import QuantizedTensor
    from repro.launch import hlo_analysis, hlo_parser
    from repro.launch.mesh import make_production_mesh
    from repro.launch.train import (abstract_train_state, batch_shardings,
                                    make_train_step, state_shardings)
    from repro.nn.models import apply_policy, build_model, input_specs

    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind, "quant": quant,
                           "kind": shape.kind}
    if shape.name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        rec.update(ok=False, skipped=True,
                   reason="full-attention arch: long_500k needs sub-quadratic mixing")
        return rec

    knobs = TRAIN_KNOBS.get(arch, {})
    if shape.kind == "train":
        rcfg = RunConfig(remat="block",
                         microbatch=knobs.get("microbatch", 1),
                         sequence_parallel=knobs.get("sequence_parallel", False),
                         opt_state_quant=knobs.get("opt", "none"))
    else:
        rcfg = RunConfig(remat="none", sequence_parallel=False,
                         serve_bf16_compute=True)
    if quant == "auto":
        quant = "bf16" if shape.kind == "train" else "pofx8"
        rec["quant"] = quant
    # a kv= rule in the policy string sizes/lowers the quantized decode
    # cache (code+scale leaves) through the XLA fallback path — the kernel
    # is validated separately and kept out of the huge dry-run graphs
    kv_spec = None
    if shape.kind != "train" and quant not in ("bf16", "fp32") \
            and cfg.family != "encdec":
        kv_spec = QuantPolicy.from_string(quant).kv_spec
        rec["kv_quant"] = bool(kv_spec)

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.size
    model = build_model(cfg, rcfg, mesh=mesh, kv_spec=kv_spec,
                        kv_kernel=False)
    repl = NamedSharding(mesh, P())

    t0 = time.time()
    batch_abs = input_specs(cfg, shape)

    if shape.kind == "train":
        state_abs = abstract_train_state(model)
        ss = state_shardings(model, state_abs)
        bs = batch_shardings(model, batch_abs)
        step = make_train_step(model, mesh)
        jitted = jax.jit(step, in_shardings=(ss, bs),
                         out_shardings=(ss, None), donate_argnums=(0,))
        args = (state_abs, batch_abs)
    else:
        # serving: weights quantized per the --quant policy string — one
        # format ("pofx8es2") or mixed rules ("attn/*=pofx8es2,*=bf16");
        # decode cache sharded + donated. Quantized leaves keep their codes
        # replicated-scale sharding tree structure (QuantizedTensor nodes).
        p_abs = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
        p_shard = model.param_shardings(p_abs)
        if quant not in ("bf16", "fp32"):
            policy = QuantPolicy.from_string(quant)
            p_abs = jax.eval_shape(
                lambda: apply_policy(model.init(jax.random.PRNGKey(0)),
                                     policy))
            flat_s, td = jax.tree_util.tree_flatten(
                p_shard, is_leaf=lambda x: x is None)
            objs = td.flatten_up_to(p_abs)
            flat_q = [QuantizedTensor(s, repl, o.spec)
                      if isinstance(o, QuantizedTensor) else s
                      for s, o in zip(flat_s, objs)]
            p_shard = jax.tree_util.tree_unflatten(td, flat_q)

        if shape.kind == "prefill":
            cache_abs = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            c_shard = model.cache_shardings(shape.global_batch, shape.seq_len)
            bs = batch_shardings(model, batch_abs)

            def prefill_step(params, cache, batch):
                return model.prefill(params, batch["tokens"], cache=cache,
                                     frames=batch.get("frames"))
            jitted = jax.jit(prefill_step,
                             in_shardings=(p_shard, c_shard, bs),
                             out_shardings=(c_shard, None),
                             donate_argnums=(1,))
            args = (p_abs, cache_abs, batch_abs)
        else:  # decode
            cache_abs = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            c_shard = model.cache_shardings(shape.global_batch, shape.seq_len)
            bs = batch_shardings(model, batch_abs)

            def decode_step(params, cache, batch):
                return model.decode_step(params, cache, batch["tokens"])
            jitted = jax.jit(decode_step,
                             in_shardings=(p_shard, c_shard, bs),
                             out_shardings=(c_shard, None),
                             donate_argnums=(1,))
            args = (p_abs, cache_abs, batch_abs)

    lowered = jitted.lower(*args)
    rec["lower_s"] = round(time.time() - t0, 2)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    # --- memory -------------------------------------------------------------
    try:
        ma = compiled.memory_analysis()
        mem = {k: int(getattr(ma, k)) for k in
               ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "alias_size_in_bytes",
                "peak_memory_in_bytes") if hasattr(ma, k)}
        # donated (aliased) args don't double-count
        live = (mem.get("argument_size_in_bytes", 0)
                + mem.get("output_size_in_bytes", 0)
                - mem.get("alias_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0))
        mem["live_bytes_per_device"] = live
        rec["memory"] = mem
        if verbose:
            print(f"[memory/device] args={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"out={mem.get('output_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
                  f"peak={mem.get('peak_memory_in_bytes', 0)/2**30:.2f}GiB "
                  f"live={live/2**30:.2f}GiB")
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}

    # --- cost: trip-count-aware HLO walk (hlo_parser) -------------------------
    # XLA's own cost_analysis counts scan bodies ONCE (verified); kept only
    # as a reference field. The roofline uses analyze_hlo.
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        rec["xla_cost_reference"] = {
            "flops_per_device_body_once": float(ca.get("flops", 0.0)),
            "bytes_per_device_body_once": float(ca.get("bytes accessed", 0.0))}
    except Exception as e:  # pragma: no cover
        rec["xla_cost_reference"] = {"error": str(e)}

    txt = compiled.as_text()
    cost = hlo_parser.analyze_hlo(txt)
    flops = cost.flops_per_device
    bytes_ = cost.bytes_per_device
    rec["cost"] = {"flops_per_device": flops, "bytes_per_device": bytes_}
    rec["collectives"] = {"wire_bytes_per_device": cost.wire_bytes_per_device,
                          "by_kind": cost.wire_by_kind,
                          "n_ops": cost.n_collectives,
                          "loops": cost.loops[:32]}
    if verbose:
        print("[hlo cost/device]")
        print(cost.summary())

    # --- roofline -----------------------------------------------------------
    mf = analytic_model_flops(cfg, shape)
    rec["model_flops"] = mf
    rec["params"] = cfg.param_count()
    rec["active_params"] = cfg.active_param_count()
    rec["n_devices"] = n_dev
    rec["roofline"] = hlo_analysis.roofline_terms(
        flops, bytes_, cost.wire_bytes_per_device, mf, n_dev)
    rec["run_config"] = {"microbatch": rcfg.microbatch,
                         "sequence_parallel": rcfg.sequence_parallel,
                         "opt_state_quant": rcfg.opt_state_quant,
                         "remat": rcfg.remat}
    rec["ok"] = True
    if verbose:
        r = rec["roofline"]
        print(f"[roofline] compute={r['compute_s']*1e3:.2f}ms "
              f"memory={r['memory_s']*1e3:.2f}ms "
              f"collective={r['collective_s']*1e3:.2f}ms "
              f"bound={r['bound']} mfu_bound={r['mfu_bound']:.3f} "
              f"useful_flops_ratio={r['useful_flops_ratio']:.3f}")
    return rec


def _save(rec: Dict[str, Any], out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = _cell_path(out_dir, rec["arch"], rec["shape"], rec["mesh"],
                      rec["quant"])
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    from repro.core.policy import add_policy_arg
    add_policy_arg(ap, default="auto",
                   extra_help="'auto' = bf16 train / pofx8 serve")
    ap.add_argument("--out", default=OUT_DIR)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    if args.all:
        from repro.configs import ARCHS
        from repro.configs.base import SHAPES
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        failures = []
        for arch in ARCHS:
            for shape in SHAPES:
                for mk in meshes:
                    quant = args.quant
                    if quant == "auto":
                        quant = "bf16" if SHAPES[shape].kind == "train" else "pofx8"
                    path = _cell_path(args.out, arch, shape, mk, quant)
                    if os.path.exists(path) and not args.force:
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape, "--mesh", mk,
                           "--quant", args.quant, "--out", args.out]
                    print(f"=== {arch} x {shape} x {mk} ({args.quant})",
                          flush=True)
                    r = subprocess.run(cmd, capture_output=True, text=True)
                    if r.returncode != 0:
                        failures.append((arch, shape, mk))
                        print(r.stdout[-2000:])
                        print(r.stderr[-2000:])
        print(f"sweep done; {len(failures)} failures: {failures}")
        return 1 if failures else 0

    assert args.arch and args.shape, "--arch/--shape required without --all"
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    status = 0
    for mk in meshes:
        try:
            rec = run_cell(args.arch, args.shape, mk, args.quant, args.out)
        except Exception as e:
            rec = {"arch": args.arch, "shape": args.shape, "mesh": mk,
                   "quant": args.quant, "ok": False, "error": str(e),
                   "traceback": traceback.format_exc()}
            status = 1
        path = _save(rec, args.out)
        print(f"{'OK ' if rec.get('ok') else ('SKIP' if rec.get('skipped') else 'FAIL')} -> {path}")
        if not rec.get("ok") and not rec.get("skipped"):
            print(rec.get("error", ""))
    return status


if __name__ == "__main__":
    sys.exit(main())

"""Serving driver: continuous-batching quantized serving through the engine.

The paper's deployment story end to end: weights post-training-quantized per
a QuantPolicy — one format (``--quant pofx8es2``) or mixed per-layer formats
(``--quant "attn/*=pofx8es2,mlp/*=fxp8f7,*=bf16"``) — served by the
slot-based continuous-batching engine (``repro.launch.engine``): per-request
admission, scan-fused multi-token decode with per-slot stopping, pluggable
sampling. ``--use-kernel`` routes every quantized matmul through the fused
Pallas PoFx/FxP kernels (the paper's Move&Store accelerator datapath;
interpret mode on CPU), so quantized serving actually exercises them.
``--kv-quant fxp8`` (or a ``kv=fxp8`` rule inside ``--quant``) additionally
stores the decode KV cache as quantization codes and — with
``--use-kernel`` — attends through the fused Pallas flash-decode kernel,
cutting the S-proportional decode HBM term 2x+ (DESIGN.md §8):

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \\
        --quant pofx8 --kv-quant fxp8 --use-kernel

Token accounting: ``--gen`` is the number of tokens *generated per request*
(the first comes from the prefill logits, the remaining ``gen-1`` from
decode steps); the decode tok/s rate divides decode-generated tokens by
decode wall time, and the printed sample has exactly ``gen`` tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
        --quant pofx8 --use-kernel --prompt-len 64 --gen 32
    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \
        --temperature 0.8 --top-k 40 --arrival-gap 8 --requests 12

``--paged --page-size N`` serves through the paged KV cache (DESIGN.md
§10): a flat pool of N-token pages + per-slot block tables + a radix
prefix index, so shared system prompts skip their prefill and eviction
keeps pages resident (resume re-prefills one token). Token streams are
identical to the dense engine's; the summary adds a paging-metrics line
(prefix hit rate, resident pages, pages freed, CoW copies):

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --smoke \\
        --paged --page-size 16 --kv-quant fxp8 --use-kernel

``--legacy`` (automatic for encdec, which needs per-batch encoder frames)
runs the old one-shot fixed-batch greedy loop instead.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, RunConfig, smoke as smoke_cfg
from repro.core.policy import (QuantPolicy, add_kv_quant_arg, add_policy_arg,
                               format_spec, resolve_kv_spec, storage_report)
from repro.launch.engine import Request, SamplingParams, ServeEngine
from repro.launch.mesh import make_tp_mesh
from repro.nn.models import (apply_policy, build_model,
                             kv_decode_bytes_per_token)

# Back-compat name; the policy-aware report lives in repro.core.policy.
param_storage_report = storage_report


def _legacy_main(args, cfg, model, params) -> None:
    """One-shot fixed-batch greedy serving (the encdec path).

    Generates exactly ``args.gen`` tokens per sequence: 1 sampled from the
    prefill logits + ``gen-1`` decode steps — the reported rates divide by
    the matching counts (the old driver concatenated ``gen+1`` tokens while
    dividing by ``gen``).
    """
    B, P = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                 cfg.vocab_size)
    frames = None
    if cfg.family == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(2), (B, P, cfg.d_model),
                                   jnp.float32)
    max_len = P + args.gen
    cache = model.init_cache(B, max_len, enc_len=P)

    t0 = time.perf_counter()
    # frames is a real jit argument (not a closed-over constant): a new
    # encoder batch must not silently reuse the baked-in prefill trace.
    cache, logits = jax.jit(
        lambda p, c, t, f: model.prefill(p, t, cache=c, frames=f),
        donate_argnums=(1,))(params, cache, prompts, frames)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    tok = jnp.argmax(logits, axis=-1)[:, None]
    outs = [tok]
    n_steps = args.gen - 1
    t0 = time.perf_counter()
    for _ in range(n_steps):
        cache, logits = decode(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1)[:, None]
        outs.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.asarray(jnp.concatenate(outs, axis=1))
    assert not np.any(np.isnan(np.asarray(logits))), "NaN logits"
    print(f"prefill: {B}x{P} tokens in {t_prefill:.3f}s "
          f"({B*P/t_prefill:.0f} tok/s, +1 sampled token/seq)")
    if n_steps:
        print(f"decode:  {n_steps} steps x {B} seqs in {t_decode:.3f}s "
              f"({n_steps*B/t_decode:.1f} tok/s)")
    print(f"total:   {args.gen} tokens/seq x {B} seqs")
    print(f"sample ({gen.shape[1]} tokens):", gen[0, :16].tolist(),
          "..." if gen.shape[1] > 16 else "")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--arch", default="yi-9b", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    add_policy_arg(ap, default="pofx8")
    ap.add_argument("--use-kernel", action="store_true",
                    help="route quantized matmuls through the fused Pallas "
                         "PoFx/FxP kernels, and quantized-KV decode through "
                         "the fused flash-decode kernel (interpret mode on "
                         "CPU)")
    add_kv_quant_arg(ap)
    ap.add_argument("--batch", type=int, default=4,
                    help="engine slots (legacy: fixed batch size)")
    ap.add_argument("--requests", type=int, default=0,
                    help="requests to serve (default: 2x slots)")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32,
                    help="tokens generated per request")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    ap.add_argument("--top-k", type=int, default=0, help="0 = off")
    ap.add_argument("--eos-id", type=int, default=-1,
                    help="stop token id (<0 = none; random-weight demos "
                         "never stop early)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="decode steps fused into one scan")
    ap.add_argument("--arrival-gap", type=float, default=0.0,
                    help="virtual decode steps between request arrivals "
                         "(0 = all at once)")
    ap.add_argument("--prompt-bucket", type=int, default=1,
                    help="round prompt lengths up to this multiple for "
                         "prefill (bounds recompilation; attention "
                         "families only)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache (DESIGN.md §10): fixed-size token "
                         "pages + per-slot block tables + radix prefix "
                         "sharing; token streams identical to the dense "
                         "engine (attention families dense/moe only)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (--paged)")
    ap.add_argument("--n-pages", type=int, default=0,
                    help="page pool size (--paged; 0 = dense-equivalent "
                         "capacity + headroom)")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel devices (1-D 'tp' mesh): shards "
                         "attention heads / MLP hidden / experts and the KV "
                         "cache's head axis over N devices; greedy outputs "
                         "are token-identical to --tp 1 (DESIGN.md §9; CPU: "
                         "set XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=N)")
    ap.add_argument("--legacy", action="store_true",
                    help="one-shot fixed-batch greedy loop (no engine)")
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = smoke_cfg(cfg)
    rcfg = RunConfig(remat="none")
    policy = QuantPolicy.from_string(args.quant)
    kv_spec = resolve_kv_spec(args.kv_quant, policy)
    if kv_spec is not None and cfg.family == "encdec":
        print("(encdec: quantized KV cache unsupported on the legacy "
              "one-shot path; serving with a bf16 cache)")
        kv_spec = None
    if args.tp > 1 and (args.legacy or cfg.family == "encdec"):
        ap.error("--tp needs the engine path (not --legacy / encdec)")
    if args.paged and (args.legacy or cfg.family == "encdec"):
        ap.error("--paged needs the engine path (not --legacy / encdec)")
    mesh = make_tp_mesh(args.tp) if args.tp > 1 else None
    model = build_model(cfg, rcfg, mesh=mesh, use_kernel=args.use_kernel,
                        kv_spec=kv_spec)
    params = model.init(jax.random.PRNGKey(0))
    params = apply_policy(params, policy)
    print(f"[{args.arch} quant={policy.to_string()} "
          f"kv={format_spec(kv_spec) if kv_spec else 'bf16'} "
          f"kernel={'pallas' if args.use_kernel else 'xla-lut'}"
          f"{f' tp={args.tp}' if args.tp > 1 else ''}]")
    print(storage_report(params, policy))
    ctx_len = args.prompt_len + args.gen
    kv_q = kv_decode_bytes_per_token(cfg, ctx_len, kv_spec)
    kv_b = kv_decode_bytes_per_token(cfg, ctx_len, None)
    if kv_spec is not None and kv_q["code_bytes"]:
        print(f"  kv cache @ {ctx_len} ctx: "
              f"{kv_q['code_bytes'] / 2**10:.1f} KiB/token streamed "
              f"(+{kv_q['scale_bytes'] / 2**10:.1f} KiB static scales) vs "
              f"bf16 {kv_b['code_bytes'] / 2**10:.1f} KiB "
              f"({kv_b['code_bytes'] / kv_q['code_bytes']:.1f}x less decode "
              f"HBM traffic)")

    if args.legacy or cfg.family == "encdec":
        if not args.legacy:
            print("(encdec: engine unsupported, using one-shot path)")
        ignored = [f for f, on in (
            ("--temperature", args.temperature != 0.0),
            ("--top-k", args.top_k != 0),
            ("--requests", args.requests != 0),
            ("--arrival-gap", args.arrival_gap != 0.0),
            ("--prompt-bucket", args.prompt_bucket > 1),
            ("--eos-id", args.eos_id >= 0),
            ("--chunk", args.chunk != 8)) if on]
        if ignored:
            print(f"(legacy path is greedy fixed-batch; ignoring "
                  f"{', '.join(ignored)})")
        _legacy_main(args, cfg, model, params)
        return

    P, G = args.prompt_len, args.gen
    n_req = args.requests or 2 * args.batch
    if n_req < 1 or G < 1 or P < 1:
        ap.error("--requests/--gen/--prompt-len must be >= 1")
    if args.paged and cfg.family not in ("dense", "moe"):
        ap.error(f"--paged supports dense/moe families, not {cfg.family}")
    engine = ServeEngine(
        model, params, n_slots=args.batch, max_len=P + G,
        eos_id=args.eos_id if args.eos_id >= 0 else None,
        chunk=args.chunk, prompt_bucket=args.prompt_bucket, seed=0,
        paged=args.paged, page_size=args.page_size,
        n_pages=args.n_pages or None)
    rng = np.random.default_rng(1)
    sampling = SamplingParams(temperature=args.temperature, top_k=args.top_k)
    requests = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, P),
                max_new=G, sampling=sampling, arrival=i * args.arrival_gap)
        for i in range(n_req)
    ]
    done = engine.run(requests)

    stats = engine.stats()
    n_prefill_tok = sum(len(s.context) for s in done)
    n_gen = stats["generated_tokens"]
    n_dec = stats["decode_tokens"]      # excludes prefill-sampled tokens
    print(f"served {len(done)} requests on {args.batch} slots "
          f"(chunk={args.chunk}, arrival gap={args.arrival_gap} steps)")
    print(f"prefill: {n_prefill_tok} prompt tokens in "
          f"{engine.prefill_time:.3f}s ({n_prefill_tok/engine.prefill_time:.0f}"
          f" tok/s, +{stats['prefill_sampled_tokens']} sampled tokens)")
    print(f"decode:  {engine.decode_steps} scan steps, {n_dec} tokens in "
          f"{engine.decode_time:.3f}s ({n_dec/max(engine.decode_time,1e-9):.1f}"
          f" tok/s)")
    print(f"total:   {n_gen} generated tokens in {engine.total_time:.3f}s "
          f"({n_gen/engine.total_time:.1f} tok/s end-to-end)")
    if args.paged:
        print(f"paging:  page={engine.page_size} tok, "
              f"{stats['resident_pages']}/{engine.n_pages - 1} pages "
              f"resident, prefix hits {stats['prefix_hits']}/"
              f"{stats['prefix_queries']} "
              f"(rate {stats['prefix_hit_rate']:.2f}, "
              f"{stats['prefix_hit_tokens']} prefill tokens skipped), "
              f"{stats['pages_freed']} pages freed on evict/finish, "
              f"{stats['cow_copies']} CoW copies")
    s0 = done[0]
    if any(len(s.out) > G for s in done):  # must survive `python -O`
        raise RuntimeError("engine generated more than --gen tokens")
    print(f"sample rid=0 ({len(s0.out)} tokens, {s0.finish_reason}):",
          s0.out[:16], "..." if len(s0.out) > 16 else "")


if __name__ == "__main__":
    main()

"""Pallas TPU kernel: paged quantized-KV-cache flash-decode attention.

``kv_flash_decode`` streams a *contiguous* per-slot code cache; this kernel
is the same online-softmax decode indirected through a **block table**: the
cache is one flat pool of fixed-size token pages (byte-wide fxp/pofx codes,
DESIGN.md §10) and each slot names its pages by physical id. The block
table rides in as a scalar-prefetch operand (``PrefetchScalarGridSpec``),
so the grid's S axis walks *logical* pages while the BlockSpec index_map
DMAs the *physical* page — the indirection costs an SMEM lookup, not a
gather: only the slot's own pages ever leave HBM, and they dequantize on
the VPU in VMEM exactly as in the dense kernel.

Why this preserves the paper's bandwidth win: pages hold codes, so a page
of ``ps`` tokens moves ``ps * Dh`` bytes instead of ``2 * ps * Dh`` — and
because pages are position-masked (``idx >= pos`` lanes go to -inf),
garbage-page entries (unallocated tail of the table) and the junk beyond a
shared partial page's valid prefix are computed over but never survive the
softmax, so no per-slot trimming DMA is needed.

Oracle: ``ref.kv_flash_paged_decode_ref`` (gather pages -> dense oracle);
the XLA fallback in ``nn.attention`` computes the same gather out-of-place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantizers import QuantSpec
from . import vmem_scratch
from .kv_flash_decode import _dequant_tile

__all__ = ["kv_flash_paged_decode"]

NEG_INF = -1e30


def _kernel(tbl_ref, pos_ref, q_ref, kc_ref, ks_ref, vc_ref, vs_ref, o_ref,
            m_ref, l_ref, acc_ref, *, spec: QuantSpec, ps: int, ns: int,
            scale: float):
    b = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                     # (R, Dh)
    k = _dequant_tile(kc_ref[0, 0], spec, ks_ref[0])        # (ps, Dh)
    sc = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (R,ps)
    # logical token index of each lane in this page; everything at or past
    # the slot's valid length masks out — including the whole page when the
    # table entry is the garbage page (its logical index is past pos too)
    idx = s * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
    sc = jnp.where(idx < pos_ref[b], sc, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]                 # (R, 1)
    m_new = jnp.maximum(m_prev, sc.max(axis=-1, keepdims=True))
    p = jnp.exp(sc - m_new)                                 # (R, ps)
    corr = jnp.exp(m_prev - m_new)
    v = _dequant_tile(vc_ref[0, 0], spec, vs_ref[0])        # (ps, Dh)
    m_ref[...] = m_new
    l_ref[...] = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)

    @pl.when(s == ns - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("spec", "interpret",
                                             "out_dtype"))
def kv_flash_paged_decode(q: jax.Array, k_pool: jax.Array, k_scale: jax.Array,
                          v_pool: jax.Array, v_scale: jax.Array,
                          tables: jax.Array, pos: jax.Array,
                          spec: QuantSpec, *, interpret: bool | None = None,
                          out_dtype=jnp.float32) -> jax.Array:
    """One-token attention against a paged quantized code pool.

    q:        (B, G, R, Dh) float queries (R = q heads per kv group)
    k_pool:   (n_pages, G, ps, Dh) int8/uint8 page pool (``kv_code_dtype``)
    k_scale:  (G, 1, Dh) f32 static per-head-dim-channel normalizer —
              global per layer, NOT per slot: pages are shareable across
              requests only because every page quantizes under one grid
    v_pool / v_scale: same layouts for V
    tables:   (B, max_pages) int32 physical page ids per slot (garbage-page
              padded past the allocated prefix)
    pos:      scalar or (B,) valid-prefix lengths (mask: idx < pos)

    Returns (B, G, R, Dh) in ``out_dtype``. Grid is (B, G, max_pages) with
    the page axis innermost; the block table is a scalar-prefetch operand
    so each page's physical id resolves before its DMA is issued. The
    block length is one page — pick page_size >= the backend's lane tile
    for production TPU runs (any size works in interpret mode).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, G, R, Dh = q.shape
    n_pages, Gp, ps, Dhp = k_pool.shape
    if v_pool.shape != k_pool.shape:
        raise ValueError(
            f"k/v pool shape mismatch: {k_pool.shape} vs {v_pool.shape}")
    if (Gp, Dhp) != (G, Dh):
        raise ValueError(
            f"pool (G, Dh) {Gp, Dhp} does not match queries {(G, Dh)}")
    for name, sc in (("k_scale", k_scale), ("v_scale", v_scale)):
        if sc.shape != (G, 1, Dh):
            # must raise: the (1, Dh) BlockSpec would silently read row 0
            # of a mis-shaped scale while the XLA fallback broadcasts it
            raise ValueError(
                f"paged kv {name} must be global per-head-dim-channel "
                f"({G}, 1, {Dh}); got {sc.shape}")
    if tables.ndim != 2 or tables.shape[0] != B:
        raise ValueError(
            f"tables must be (B={B}, max_pages); got {tables.shape}")
    ns = tables.shape[1]
    pos2 = jnp.broadcast_to(jnp.reshape(pos, (-1,)), (B,)).astype(jnp.int32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # tables, pos
        grid=(B, G, ns),
        in_specs=[
            pl.BlockSpec((1, 1, R, Dh), lambda b, g, s, tbl, pos: (b, g, 0, 0)),
            pl.BlockSpec((1, 1, ps, Dh),
                         lambda b, g, s, tbl, pos: (tbl[b, s], g, 0, 0)),
            pl.BlockSpec((1, 1, Dh), lambda b, g, s, tbl, pos: (g, 0, 0)),
            pl.BlockSpec((1, 1, ps, Dh),
                         lambda b, g, s, tbl, pos: (tbl[b, s], g, 0, 0)),
            pl.BlockSpec((1, 1, Dh), lambda b, g, s, tbl, pos: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, R, Dh),
                               lambda b, g, s, tbl, pos: (b, g, 0, 0)),
        scratch_shapes=[vmem_scratch((R, 1)), vmem_scratch((R, 1)),
                        vmem_scratch((R, Dh))],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, spec=spec, ps=ps, ns=ns,
                          scale=Dh ** -0.5),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, G, R, Dh), out_dtype),
        interpret=interpret,
    )(tables.astype(jnp.int32), pos2, q.astype(jnp.float32), k_pool,
      k_scale.astype(jnp.float32), v_pool, v_scale.astype(jnp.float32))
    return out

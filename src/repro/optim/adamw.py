"""AdamW with warmup+cosine schedule, global-norm clipping and (beyond
paper) posit8-compressed optimizer moments.

Moment compression is the paper's storage idea applied to training state:
Adam's m/v are stored as 8-bit Posit(8,2) codes with one power-of-two
per-tensor scale — the tapered posit lattice matches the heavy-near-zero
distribution of moments exactly like it matches trained weights (Fig. 1 of
the paper). Storage: 1 byte/param per moment instead of 4 (m) + 4 (v).
Decode/encode ride the same jnp posit codec the PoFx path uses; on TPU the
encode lowers to a 7-step branchless binary search over the 128-entry code
lattice (log2 table) — negligible next to the grad computation.

State layout (a plain pytree of dicts so checkpointing is trivial):
  {"m": tree, "v": tree, "count": i32 scalar}
where each tree leaf is either an f32 array (quant="none") or a
QuantizedTensor (quant="posit8").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantizers import QuantSpec, QuantizedTensor, dequantize, quantize

__all__ = ["OptConfig", "init_opt_state", "apply_updates", "lr_schedule",
           "global_norm"]

_POSIT8 = QuantSpec(kind="posit", N=8, ES=2, scale_mode="tensor_pow2")


@dataclasses.dataclass(frozen=True)
class OptConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quant: str = "none"          # none | posit8


def lr_schedule(step: jax.Array, ocfg: OptConfig) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(ocfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - ocfg.warmup_steps)
                 / jnp.maximum(ocfg.total_steps - ocfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = ocfg.min_lr_frac + (1 - ocfg.min_lr_frac) * cos
    return ocfg.learning_rate * warm * frac


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def _zeros_like_moment(p, quant: str):
    z = jnp.zeros(p.shape, jnp.float32)
    if quant == "posit8":
        return quantize(z, _POSIT8)
    return z


def init_opt_state(params, quant: str = "none") -> Dict[str, Any]:
    m = jax.tree.map(lambda p: _zeros_like_moment(p, quant), params)
    v = jax.tree.map(lambda p: _zeros_like_moment(p, quant), params)
    return {"m": m, "v": v, "count": jnp.zeros((), jnp.int32)}


def _load(x) -> jax.Array:
    if isinstance(x, QuantizedTensor):
        return dequantize(x, jnp.float32)
    return x.astype(jnp.float32)


def _store(x, quant: str):
    if quant == "posit8":
        return quantize(x, _POSIT8)
    return x


def apply_updates(params, grads, opt_state, ocfg: OptConfig
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step. Returns (params, opt_state, metrics)."""
    count = opt_state["count"] + 1
    lr = lr_schedule(count, ocfg)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, ocfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if ocfg.grad_clip > 0 else jnp.asarray(1.0)

    b1, b2 = ocfg.b1, ocfg.b2
    c1 = 1 - b1 ** count.astype(jnp.float32)
    c2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        mf = b1 * _load(m) + (1 - b1) * g
        vf = b2 * _load(v) + (1 - b2) * jnp.square(g)
        mhat = mf / c1
        vhat = vf / c2
        step = mhat / (jnp.sqrt(vhat) + ocfg.eps)
        if ocfg.weight_decay and p.ndim >= 2:
            step = step + ocfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return newp, _store(mf, ocfg.quant), _store(vf, ocfg.quant)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    is_qt = lambda x: isinstance(x, QuantizedTensor)
    flat_m = jax.tree.flatten(opt_state["m"], is_leaf=is_qt)[0]
    flat_v = jax.tree.flatten(opt_state["v"], is_leaf=is_qt)[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm,
               "param_norm": global_norm(flat_p)}
    return new_p, {"m": new_m, "v": new_v, "count": count}, metrics

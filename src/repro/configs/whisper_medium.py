"""whisper-medium [audio]: 24+24L enc-dec d_model=1024 16H d_ff=4096
vocab=51865 — conv frontend is a STUB (input_specs provides precomputed
frame embeddings) [arXiv:2212.04356]. Plain (non-gated) GELU MLPs."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_head=64, d_ff=4096, vocab_size=51865, act="gelu_plain",
    frontend="stub_audio", rope_theta=10000.0,
)
